//! Parallel sharded serving path: a [`ServingEngine`] routes batches
//! across scoped worker threads over one shared [`RouterPlan`], and —
//! since PR 2 — runs the **full expert-parallel data path**
//! ([`ServingEngine::forward_full`]): route → compile a
//! [`DispatchPlan`] → real expert FFN compute → gate-weighted combine.
//!
//! Routing shard model: a batch of `N` tokens is split into `T`
//! contiguous shards (first `N mod T` shards get one extra token). Each
//! worker routes its shard with its own persistent [`RouteBuffers`] +
//! [`RouterBatch`] (no sharing, no locks), writing a disjoint token
//! range. After the scope joins, shard outputs are merged **in shard
//! order**: ids/weights are copied into their flat `[N*k]` positions and
//! per-shard load histograms are summed.
//!
//! Expert-compute shard model: the compiled plan's grouped-GEMM layout
//! is split into `T` *contiguous expert ranges* balanced by row count
//! (boundaries depend only on the plan, never on thread timing); each
//! worker runs its experts' FFN buckets into a disjoint row range of
//! the grouped output. Per-expert compute is pure, and the final
//! combine walks tokens in fixed (token, slot) order on the caller's
//! thread — so the full forward output is bit-identical for every
//! thread count, exactly like routing.
//!
//! Threads are spawned per call via `std::thread::scope` (only the
//! shard *buffers* persist across calls) — spawn+join costs tens of
//! microseconds, so multi-threading pays off on large batches or
//! expensive kernels; tiny batches run inline on the caller's thread.
//! For sustained serving traffic, [`crate::serve::PoolEngine`] runs the
//! same pipeline on a **persistent channel-fed worker pool** instead;
//! it shares this module's partition helpers ([`shard_span`],
//! `expert_group_bounds`) and merge/compute steps (`merge_route_shard`,
//! `run_expert_rows` — the row-granular sibling of `run_expert_range`
//! that expert placement splits replicated buckets with), so pool
//! outputs are bit-identical to the scoped path for every worker count
//! (pinned by `pool_forward_full_matches_scoped_engine` in
//! `serve::pool`).
//!
//! Thread-determinism contract: token routing is per-token pure, shard
//! boundaries depend only on `(N, T)` (routing) or the plan's offsets
//! (experts), and merge/combine orders are fixed — so `route(h)` and
//! `forward_full(h, ..)` are bit-identical for every thread count,
//! including 1 (pinned by `multi_thread_matches_single_thread` and
//! `forward_full_bit_identical_across_thread_counts`). Load counts are
//! small integers in f32, so even summation order cannot perturb them.

use super::plan::{RouteBuffers, RouterBatch, RouterPlan};
use crate::dispatch::plan::{capacity_for, DispatchPlan, OverflowPolicy};
use crate::experts::{combine_rows_opts, gather_rows, ExpertBank};
use crate::kernels::{GemmTiles, Kernel};
use crate::metrics::{LoadTracker, DEFAULT_LOAD_WINDOW};

/// Token range of shard `i` when `n` tokens split into `t` contiguous
/// shards: the first `n mod t` shards get one extra token. This is the
/// single shard rule shared by [`ServingEngine`] (scoped threads) and
/// `serve::PoolEngine` (persistent workers) — part of the
/// thread-determinism contract: boundaries depend only on `(n, t, i)`,
/// never on thread timing.
pub fn shard_span(n: usize, t: usize, i: usize) -> std::ops::Range<usize> {
    let (base, rem) = (n / t, n % t);
    let start = i * base + i.min(rem);
    start..start + base + usize::from(i < rem)
}

/// Copy one routed shard into its token range of `out` and accumulate
/// its load histogram — the fixed merge step run in shard order by both
/// serving paths. `out` must already be `reset` for the full batch.
pub(crate) fn merge_route_shard(
    out: &mut RouterBatch,
    shard: &RouterBatch,
    start: usize,
) {
    let k = out.top_k;
    out.topk_idx[start * k..start * k + shard.topk_idx.len()]
        .copy_from_slice(&shard.topk_idx);
    out.weights[start * k..start * k + shard.weights.len()]
        .copy_from_slice(&shard.weights);
    for (acc, &l) in out.load.iter_mut().zip(&shard.load) {
        *acc += l;
    }
}

/// Expert-group boundaries for the compute stage: `groups + 1` indices
/// into `plan`'s expert range, chosen so each group covers a contiguous
/// expert span with roughly `kept / groups` grouped rows. Depends only
/// on the plan's offsets — the same partition for every thread count.
pub(crate) fn expert_group_bounds(
    plan: &DispatchPlan,
    groups: usize,
    bounds: &mut Vec<usize>,
) {
    let kept = plan.kept();
    bounds.clear();
    bounds.reserve(groups + 1);
    for g in 0..=groups {
        let target = (kept * g / groups) as u32;
        bounds.push(plan.offsets.partition_point(|&o| o < target));
    }
}

/// Run the FFN buckets of experts `e0..e1` over the gathered rows `xg`
/// with GEMM kernel `kernel`, writing grouped rows
/// `offsets[e0]..offsets[e1]` into `ys` (which holds exactly that
/// sub-range). Pure per expert for every kernel, so any thread may
/// execute a group — shared by the scoped engine and the pool workers.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_expert_range(
    bank: &ExpertBank,
    plan: &DispatchPlan,
    xg: &[f32],
    e0: usize,
    e1: usize,
    d: usize,
    kernel: Kernel,
    tiles: GemmTiles,
    hid: &mut Vec<f32>,
    ys: &mut [f32],
) {
    let row0 = plan.offsets[e0] as usize;
    let mut cursor = 0usize;
    for ei in e0..e1 {
        let rows = plan.expert_rows(ei);
        let m = rows.len();
        if m == 0 {
            continue;
        }
        bank.forward_rows_tiled(
            kernel,
            tiles,
            ei,
            &xg[rows.start * d..rows.end * d],
            m,
            hid,
            &mut ys[cursor..cursor + m * d],
        );
        cursor += m * d;
    }
    debug_assert_eq!(cursor, (plan.offsets[e1] as usize - row0) * d);
}

/// Run the FFN compute for grouped rows `row0..row1` — a row range
/// that may start or stop **mid-bucket** — writing `(row1 - row0) * d`
/// values into `ys`. The generalization of [`run_expert_range`] that
/// expert placement needs: a replicated expert's bucket is split
/// across workers at row granularity, so a worker's share is a row
/// span, not a whole expert range. Per-row FFN outputs depend only on
/// the input row and the expert weights (independent of row batching —
/// pinned per kernel in `experts`), so any partition of rows across
/// workers is bit-identical to running the buckets whole.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_expert_rows(
    bank: &ExpertBank,
    plan: &DispatchPlan,
    xg: &[f32],
    row0: usize,
    row1: usize,
    d: usize,
    kernel: Kernel,
    tiles: GemmTiles,
    hid: &mut Vec<f32>,
    ys: &mut [f32],
) {
    let mut cursor = 0usize;
    let mut r = row0;
    while r < row1 {
        // the bucket holding grouped row r: offsets[e] <= r < offsets[e+1]
        let e = plan.offsets.partition_point(|&o| o <= r as u32) - 1;
        let end = (plan.offsets[e + 1] as usize).min(row1);
        let m = end - r;
        bank.forward_rows_tiled(
            kernel,
            tiles,
            e,
            &xg[r * d..end * d],
            m,
            hid,
            &mut ys[cursor..cursor + m * d],
        );
        cursor += m * d;
        r = end;
    }
    debug_assert_eq!(cursor, (row1 - row0) * d);
}

/// A reusable routing engine: owns the compiled plan plus per-shard
/// scratch, so steady-state `route_into` / `forward_full` calls
/// allocate nothing.
#[derive(Debug)]
pub struct ServingEngine {
    plan: RouterPlan,
    n_threads: usize,
    shards: Vec<Shard>,
    /// Rolling routed-load window over this engine's batches.
    tracker: LoadTracker,
    /// Renormalize surviving gate weights of partially-dropped tokens
    /// in the combine (see [`combine_rows_opts`]); off by default.
    renormalize: bool,
    /// GEMM micro-kernel for the expert FFN stage (the
    /// `Engine::builder().kernel(..)` knob); [`Kernel::Naive`] by
    /// default, which is bit-identical to the historic path.
    kernel: Kernel,
    /// MC×KC×NC cache tiles for the blocked/SIMD GEMM paths (the
    /// `Engine::builder().gemm_tiles(..)` knob). A pure cache knob:
    /// every kernel is bitwise tile-invariant.
    tiles: GemmTiles,
}

#[derive(Debug, Clone, Default)]
struct Shard {
    buf: RouteBuffers,
    out: RouterBatch,
    /// FFN hidden-activation scratch for the expert-compute stage.
    hid: Vec<f32>,
}

/// Reusable output + scratch of [`ServingEngine::forward_full`]: the
/// routed batch, the compiled dispatch plan, and the `[N, d]` combined
/// token vectors (gather/grouped buffers are kept internally so
/// steady-state calls do not allocate).
#[derive(Debug, Clone, Default)]
pub struct FullForward {
    pub batch: RouterBatch,
    pub plan: DispatchPlan,
    /// `[N, d]` gate-weighted combined expert outputs, token order.
    /// Tokens whose every slot was dropped are all-zero rows (they
    /// continue through the residual stream).
    pub combined: Vec<f32>,
    /// `[kept, d]` expert-grouped gathered inputs.
    xg: Vec<f32>,
    /// `[kept, d]` expert-grouped FFN outputs (also written by
    /// `serve::PoolEngine`, which gathers into its own shared state).
    pub(crate) y: Vec<f32>,
}

impl FullForward {
    pub fn new() -> FullForward {
        FullForward::default()
    }

    /// Combined vector of token `r`.
    pub fn token_row(&self, r: usize) -> &[f32] {
        let d = self.combined.len() / self.plan.n.max(1);
        &self.combined[r * d..(r + 1) * d]
    }
}

impl ServingEngine {
    /// `n_threads` is clamped to at least 1; 1 routes inline on the
    /// caller's thread.
    pub fn new(plan: RouterPlan, n_threads: usize) -> ServingEngine {
        let n_threads = n_threads.max(1);
        let n_experts = plan.cfg.n_experts;
        ServingEngine {
            shards: vec![Shard::default(); n_threads],
            n_threads,
            tracker: LoadTracker::new(DEFAULT_LOAD_WINDOW, n_experts),
            plan,
            renormalize: false,
            kernel: Kernel::default(),
            tiles: GemmTiles::default(),
        }
    }

    pub fn plan(&self) -> &RouterPlan {
        &self.plan
    }

    pub fn n_threads(&self) -> usize {
        self.n_threads
    }

    /// Enable/disable gate-weight renormalization for partially-dropped
    /// tokens in [`Self::forward_full`]'s combine (the `--renormalize`
    /// CLI option). Off by default; with no drops the output is
    /// bit-identical either way (see [`combine_rows_opts`]).
    pub fn set_renormalize(&mut self, on: bool) {
        self.renormalize = on;
    }

    /// Select the GEMM micro-kernel for the expert FFN stage. Every
    /// kernel keeps the bit-identical-across-threads contract; only
    /// [`Kernel::Naive`] (the default) is additionally bit-identical
    /// to the historic goldens (see [`crate::kernels`]).
    pub fn set_kernel(&mut self, kernel: Kernel) {
        self.kernel = kernel;
    }

    /// Select the MC×KC×NC cache tiles for the expert FFN GEMMs (the
    /// `Engine::builder().gemm_tiles(..)` knob). Tiles move cache
    /// behaviour, never bits; the caller (the builder) validates them.
    pub fn set_gemm_tiles(&mut self, tiles: GemmTiles) {
        self.tiles = tiles;
    }

    /// Rolling balance of the batches this engine has routed.
    pub fn tracker(&self) -> &LoadTracker {
        &self.tracker
    }

    /// Route `h` ([N, d] row-major) into `out`. Output is identical to
    /// `self.plan().forward_into(..)` regardless of thread count.
    pub fn route_into(&mut self, h: &[f32], out: &mut RouterBatch) {
        let d = self.plan.cfg.d_model;
        assert_eq!(h.len() % d, 0, "h must be [N, {d}]");
        let n = h.len() / d;
        let (e, k) = (self.plan.cfg.n_experts, self.plan.cfg.top_k);
        // tiny batches: spawn overhead dominates, route inline
        if self.n_threads == 1 || n < 2 * self.n_threads {
            let shard = &mut self.shards[0];
            self.plan.forward_into(h, &mut shard.buf, out);
            self.tracker.push(&out.load);
            return;
        }
        let n_threads = self.n_threads;
        let plan = &self.plan;
        std::thread::scope(|scope| {
            for (t, shard) in self.shards.iter_mut().enumerate() {
                let span = shard_span(n, n_threads, t);
                let hs = &h[span.start * d..span.end * d];
                scope.spawn(move || {
                    plan.forward_into(hs, &mut shard.buf, &mut shard.out);
                });
            }
        });
        // deterministic merge in shard order
        out.reset(n, k, e);
        for (t, shard) in self.shards.iter().enumerate() {
            merge_route_shard(out, &shard.out, shard_span(n, n_threads, t).start);
        }
        self.tracker.push(&out.load);
    }

    /// Allocating convenience wrapper around [`Self::route_into`].
    pub fn route(&mut self, h: &[f32]) -> RouterBatch {
        let mut out = RouterBatch::new();
        self.route_into(h, &mut out);
        out
    }

    /// The full expert-parallel data path for one batch: route `h`,
    /// compile the routed batch into a capacity-binned [`DispatchPlan`]
    /// under `policy`, run the real expert FFNs over the grouped
    /// layout (sharded across this engine's threads), and combine the
    /// gate-weighted outputs back into token order in `out.combined`.
    ///
    /// Bit-identical for every thread count (see module docs).
    #[deprecated(
        note = "use the engine facade: Engine::builder()…backend(\
                Backend::Scoped { .. }).build() and MoeEngine::forward \
                (this engine is a backend internal now)"
    )]
    pub fn forward_full(
        &mut self,
        h: &[f32],
        bank: &ExpertBank,
        capacity_factor: f64,
        policy: OverflowPolicy,
        out: &mut FullForward,
    ) {
        let (d, e) = (self.plan.cfg.d_model, self.plan.cfg.n_experts);
        assert_eq!(bank.d_model, d, "expert bank d_model mismatch");
        assert_eq!(bank.n_experts, e, "expert bank expert count mismatch");
        // 1. route (sharded, deterministic)
        self.route_into(h, &mut out.batch);
        // 2. compile the dispatch plan (shared capacity rule)
        let cap =
            capacity_for(out.batch.topk_idx.len(), e, capacity_factor);
        out.plan.compile_batch(&out.batch, cap, policy);
        // 3. gather surviving tokens into the grouped-GEMM layout
        let FullForward { batch, plan, combined, xg, y } = out;
        let plan: &DispatchPlan = plan;
        gather_rows(plan, h, d, xg);
        // 4. expert FFN compute over contiguous per-expert buckets
        let kept = plan.kept();
        y.clear();
        y.resize(kept * d, 0.0);
        let groups = self.n_threads.min(e).max(1);
        let kernel = self.kernel;
        let tiles = self.tiles;
        if groups == 1 || kept < 2 * self.n_threads {
            let shard = &mut self.shards[0];
            bank.forward_all_tiled(
                kernel,
                tiles,
                plan,
                xg,
                &mut shard.hid,
                y,
            );
        } else {
            // contiguous expert ranges balanced by grouped-row count;
            // boundaries depend only on the plan's offsets, so the
            // partition (hence every expert's input rows) is the same
            // for every thread count
            let xg: &[f32] = xg;
            let mut bounds = Vec::with_capacity(groups + 1);
            expert_group_bounds(plan, groups, &mut bounds);
            std::thread::scope(|scope| {
                let mut y_rest: &mut [f32] = y;
                for (g, shard) in
                    self.shards.iter_mut().take(groups).enumerate()
                {
                    let (e0, e1) = (bounds[g], bounds[g + 1]);
                    let row0 = plan.offsets[e0] as usize;
                    let row1 = plan.offsets[e1] as usize;
                    let (ys, rest) =
                        y_rest.split_at_mut((row1 - row0) * d);
                    y_rest = rest;
                    if row1 == row0 {
                        continue; // no rows in this group
                    }
                    scope.spawn(move || {
                        run_expert_range(
                            bank, plan, xg, e0, e1, d, kernel, tiles,
                            &mut shard.hid, ys,
                        );
                    });
                }
            });
        }
        // 5. gate-weighted combine, fixed (token, slot) order
        combine_rows_opts(
            plan,
            &batch.weights,
            y,
            d,
            self.renormalize,
            combined,
        );
    }
}

#[cfg(test)]
#[allow(deprecated)] // the legacy full forward IS the unit under test
mod tests {
    use super::*;
    use crate::router::synthetic_lpr_router;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    /// The determinism contract: identical outputs for every thread
    /// count, including batch sizes that do not divide evenly.
    #[test]
    fn multi_thread_matches_single_thread() {
        let mut rng = Rng::new(9);
        for metric in ["cosine", "xattn", "kl"] {
            let r = synthetic_lpr_router(metric, &mut rng, 16, 8, 6, 2);
            let plan = r.plan().clone();
            for n in [1usize, 7, 103] {
                let h = rand_vec(&mut rng, n * 16);
                let mut single = ServingEngine::new(plan.clone(), 1);
                let want = single.route(&h);
                for threads in [2usize, 3, 4, 8] {
                    let mut eng =
                        ServingEngine::new(plan.clone(), threads);
                    let got = eng.route(&h);
                    assert_eq!(
                        got, want,
                        "{metric}: n={n} threads={threads} diverged"
                    );
                }
            }
        }
    }

    #[test]
    fn engine_matches_plan_forward() {
        let mut rng = Rng::new(21);
        let r = synthetic_lpr_router("gaussian", &mut rng, 16, 8, 6, 2);
        let plan = r.plan().clone();
        let h = rand_vec(&mut rng, 64 * 16);
        let want = plan.forward(&h);
        let mut eng = ServingEngine::new(plan, 4);
        assert_eq!(eng.route(&h), want);
    }

    #[test]
    fn load_conserved_across_shards() {
        let mut rng = Rng::new(33);
        let r = synthetic_lpr_router("dot", &mut rng, 16, 8, 6, 3);
        let mut eng = ServingEngine::new(r.plan().clone(), 3);
        let h = rand_vec(&mut rng, 50 * 16);
        let out = eng.route(&h);
        let total: f32 = out.load.iter().sum();
        assert_eq!(total as usize, 50 * 3);
        assert_eq!(out.topk_idx.len(), 50 * 3);
        assert_eq!(out.weights.len(), 50 * 3);
        // the engine tracker saw exactly this batch
        assert_eq!(eng.tracker().total_steps(), 1);
        assert_eq!(eng.tracker().windowed(), out.load);
    }

    /// Acceptance: the full route → plan → expert compute → combine
    /// path is bit-identical across thread counts, for every overflow
    /// policy, including ragged batch sizes.
    #[test]
    fn forward_full_bit_identical_across_thread_counts() {
        let mut rng = Rng::new(51);
        let (d, dz, e, k, ff_dim) = (16usize, 8, 8, 3, 12);
        let bank = ExpertBank::new(&Rng::new(3), e, d, ff_dim);
        for metric in ["cosine", "kl"] {
            let r = synthetic_lpr_router(metric, &mut rng, d, dz, e, k);
            let plan = r.plan().clone();
            for n in [5usize, 97] {
                let h = rand_vec(&mut rng, n * d);
                for policy in OverflowPolicy::ALL {
                    let mut single =
                        ServingEngine::new(plan.clone(), 1);
                    let mut want = FullForward::new();
                    single.forward_full(&h, &bank, 1.0, policy, &mut want);
                    for threads in [2usize, 3, 8] {
                        let mut eng =
                            ServingEngine::new(plan.clone(), threads);
                        let mut got = FullForward::new();
                        eng.forward_full(
                            &h, &bank, 1.0, policy, &mut got,
                        );
                        assert_eq!(
                            got.combined, want.combined,
                            "{metric}: n={n} t={threads} {} combined \
                             diverged",
                            policy.name()
                        );
                        assert_eq!(got.plan, want.plan);
                        assert_eq!(got.batch, want.batch);
                    }
                }
            }
        }
    }

    /// The sharded full forward must equal the hand-assembled
    /// single-threaded reference pipeline over the same plan.
    #[test]
    fn forward_full_matches_manual_pipeline() {
        use crate::experts::{combine_rows, gather_rows};
        let mut rng = Rng::new(61);
        let (d, dz, e, k, n, ff_dim) = (16usize, 8, 6, 2, 48, 10);
        let r = synthetic_lpr_router("dot", &mut rng, d, dz, e, k);
        let bank = ExpertBank::new(&Rng::new(8), e, d, ff_dim);
        let h = rand_vec(&mut rng, n * d);
        let mut eng = ServingEngine::new(r.plan().clone(), 4);
        let mut out = FullForward::new();
        eng.forward_full(
            &h,
            &bank,
            1.25,
            OverflowPolicy::NextChoice,
            &mut out,
        );

        let batch = r.plan().forward(&h);
        let cap = capacity_for(batch.topk_idx.len(), e, 1.25);
        let mut plan = DispatchPlan::new();
        plan.compile_batch(&batch, cap, OverflowPolicy::NextChoice);
        let (mut xg, mut hid, mut combined) =
            (Vec::new(), Vec::new(), Vec::new());
        gather_rows(&plan, &h, d, &mut xg);
        let mut y = vec![0.0f32; plan.kept() * d];
        bank.forward_all(&plan, &xg, &mut hid, &mut y);
        combine_rows(&plan, &batch.weights, &y, d, &mut combined);

        assert_eq!(out.batch, batch);
        assert_eq!(out.plan, plan);
        assert_eq!(out.combined, combined);
        assert_eq!(out.token_row(0).len(), d);
    }

    #[test]
    fn shard_spans_partition_the_batch() {
        for n in [0usize, 1, 7, 64, 103] {
            for t in [1usize, 2, 3, 8] {
                let mut next = 0usize;
                for i in 0..t {
                    let span = shard_span(n, t, i);
                    assert_eq!(span.start, next, "n={n} t={t} i={i}");
                    next = span.end;
                }
                assert_eq!(next, n, "spans must cover n={n} for t={t}");
            }
        }
        // first n % t shards carry the extra token
        assert_eq!(shard_span(7, 3, 0), 0..3);
        assert_eq!(shard_span(7, 3, 1), 3..5);
        assert_eq!(shard_span(7, 3, 2), 5..7);
    }

    /// With a capacity that never drops, renormalization is inert:
    /// outputs are bit-identical with the option on or off.
    #[test]
    fn renormalize_is_inert_without_drops() {
        let mut rng = Rng::new(83);
        let (d, dz, e, k, n) = (16usize, 8, 6, 2, 40);
        let r = synthetic_lpr_router("cosine", &mut rng, d, dz, e, k);
        let bank = ExpertBank::new(&Rng::new(6), e, d, 8);
        let h = rand_vec(&mut rng, n * d);
        let mut plain = ServingEngine::new(r.plan().clone(), 2);
        let mut renorm = ServingEngine::new(r.plan().clone(), 2);
        renorm.set_renormalize(true);
        let (mut a, mut b) = (FullForward::new(), FullForward::new());
        // capacity factor e (= one bin per token-slot) cannot overflow
        let cf = e as f64;
        plain.forward_full(&h, &bank, cf, OverflowPolicy::Drop, &mut a);
        renorm.forward_full(&h, &bank, cf, OverflowPolicy::Drop, &mut b);
        assert_eq!(a.plan.n_dropped, 0);
        assert_eq!(a.combined, b.combined);
    }

    #[test]
    fn forward_full_reuses_buffers() {
        let mut rng = Rng::new(71);
        let (d, dz, e, k) = (16usize, 8, 6, 2);
        let r = synthetic_lpr_router("cosine", &mut rng, d, dz, e, k);
        let bank = ExpertBank::new(&Rng::new(1), e, d, 8);
        let mut eng = ServingEngine::new(r.plan().clone(), 2);
        let mut out = FullForward::new();
        let h1 = rand_vec(&mut rng, 32 * d);
        eng.forward_full(&h1, &bank, 1.25, OverflowPolicy::Drop, &mut out);
        let first = out.combined.clone();
        // a smaller batch must fully overwrite the outputs
        let h2 = rand_vec(&mut rng, 8 * d);
        eng.forward_full(&h2, &bank, 1.25, OverflowPolicy::Drop, &mut out);
        assert_eq!(out.combined.len(), 8 * d);
        assert_eq!(out.plan.n, 8);
        // and re-running h1 reproduces the first result exactly
        eng.forward_full(&h1, &bank, 1.25, OverflowPolicy::Drop, &mut out);
        assert_eq!(out.combined, first);
    }

    /// Satellite: the determinism contract holds per kernel — each of
    /// Naive/Blocked/Simd/Neon is bit-identical to *itself* across
    /// thread counts {1, 2, 3, 8}, on shapes that straddle the tile
    /// sizes, for a plain **and** a gated (SwiGLU) bank, at default
    /// **and** deliberately-awkward cache tiles. (Cross-kernel
    /// equality is separately pinned for Naive=Blocked on f32 in
    /// `kernels` and `experts`.)
    #[test]
    fn every_kernel_bit_identical_across_thread_counts() {
        let mut rng = Rng::new(93);
        let (d, dz, e, k, ff_dim) = (16usize, 8, 6, 2, 40);
        let plain = ExpertBank::new(&Rng::new(4), e, d, ff_dim);
        let gated = ExpertBank::from_weights_gated(
            e,
            d,
            ff_dim,
            rand_vec(&mut rng, e * d * ff_dim),
            rand_vec(&mut rng, e * d * ff_dim),
            rand_vec(&mut rng, e * ff_dim * d),
        );
        let r = synthetic_lpr_router("cosine", &mut rng, d, dz, e, k);
        let plan = r.plan().clone();
        for bank in [&plain, &gated] {
            for n in [5usize, 73] {
                let h = rand_vec(&mut rng, n * d);
                for kernel in Kernel::ALL {
                    for tiles in
                        [GemmTiles::default(), GemmTiles::new(2, 3, 5)]
                    {
                        let mut single =
                            ServingEngine::new(plan.clone(), 1);
                        single.set_kernel(kernel);
                        single.set_gemm_tiles(tiles);
                        let mut want = FullForward::new();
                        single.forward_full(
                            &h,
                            bank,
                            1.0,
                            OverflowPolicy::Drop,
                            &mut want,
                        );
                        for threads in [2usize, 3, 8] {
                            let mut eng = ServingEngine::new(
                                plan.clone(),
                                threads,
                            );
                            eng.set_kernel(kernel);
                            eng.set_gemm_tiles(tiles);
                            let mut got = FullForward::new();
                            eng.forward_full(
                                &h,
                                bank,
                                1.0,
                                OverflowPolicy::Drop,
                                &mut got,
                            );
                            assert_eq!(
                                got.combined,
                                want.combined,
                                "kernel {} gated={} n={n} \
                                 t={threads} tiles {tiles} diverged",
                                kernel.name(),
                                bank.is_gated()
                            );
                        }
                    }
                }
            }
        }
    }
}
