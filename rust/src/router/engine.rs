//! Parallel sharded serving path: a [`ServingEngine`] routes batches
//! across scoped worker threads over one shared [`RouterPlan`].
//!
//! Sharding model: a batch of `N` tokens is split into `T` contiguous
//! shards (first `N mod T` shards get one extra token). Each worker
//! routes its shard with its own persistent [`RouteBuffers`] +
//! [`RouterBatch`] (no sharing, no locks), writing a disjoint token
//! range. After the scope joins, shard outputs are merged **in shard
//! order**: ids/weights are copied into their flat `[N*k]` positions and
//! per-shard load histograms are summed.
//!
//! Threads are spawned per `route_into` call via `std::thread::scope`
//! (only the shard *buffers* persist across calls) — spawn+join costs
//! tens of microseconds, so multi-threading pays off on large batches
//! or expensive kernels; tiny batches route inline on the caller's
//! thread. A persistent channel-fed worker pool is the follow-up once
//! the async serving PR lands.
//!
//! Thread-determinism contract: token routing is per-token pure, shard
//! boundaries depend only on `(N, T)`, and the merge order is fixed —
//! so `route(h)` is bit-identical for every thread count, including 1
//! (pinned by `multi_thread_matches_single_thread`). Load counts are
//! small integers in f32, so even summation order cannot perturb them.

use super::plan::{RouteBuffers, RouterBatch, RouterPlan};

/// A reusable routing engine: owns the compiled plan plus per-shard
/// scratch, so steady-state `route_into` calls allocate nothing.
#[derive(Debug)]
pub struct ServingEngine {
    plan: RouterPlan,
    n_threads: usize,
    shards: Vec<Shard>,
}

#[derive(Debug, Clone, Default)]
struct Shard {
    buf: RouteBuffers,
    out: RouterBatch,
}

impl ServingEngine {
    /// `n_threads` is clamped to at least 1; 1 routes inline on the
    /// caller's thread.
    pub fn new(plan: RouterPlan, n_threads: usize) -> ServingEngine {
        let n_threads = n_threads.max(1);
        ServingEngine {
            shards: vec![Shard::default(); n_threads],
            n_threads,
            plan,
        }
    }

    pub fn plan(&self) -> &RouterPlan {
        &self.plan
    }

    pub fn n_threads(&self) -> usize {
        self.n_threads
    }

    /// Route `h` ([N, d] row-major) into `out`. Output is identical to
    /// `self.plan().forward_into(..)` regardless of thread count.
    pub fn route_into(&mut self, h: &[f32], out: &mut RouterBatch) {
        let d = self.plan.cfg.d_model;
        assert_eq!(h.len() % d, 0, "h must be [N, {d}]");
        let n = h.len() / d;
        let (e, k) = (self.plan.cfg.n_experts, self.plan.cfg.top_k);
        // tiny batches: spawn overhead dominates, route inline
        if self.n_threads == 1 || n < 2 * self.n_threads {
            let shard = &mut self.shards[0];
            self.plan.forward_into(h, &mut shard.buf, out);
            return;
        }
        let base = n / self.n_threads;
        let rem = n % self.n_threads;
        let plan = &self.plan;
        std::thread::scope(|scope| {
            let mut start = 0usize;
            for (t, shard) in self.shards.iter_mut().enumerate() {
                let len = base + usize::from(t < rem);
                let hs = &h[start * d..(start + len) * d];
                scope.spawn(move || {
                    plan.forward_into(hs, &mut shard.buf, &mut shard.out);
                });
                start += len;
            }
        });
        // deterministic merge in shard order
        out.reset(n, k, e);
        let mut start = 0usize;
        for (t, shard) in self.shards.iter().enumerate() {
            let len = base + usize::from(t < rem);
            out.topk_idx[start * k..(start + len) * k]
                .copy_from_slice(&shard.out.topk_idx);
            out.weights[start * k..(start + len) * k]
                .copy_from_slice(&shard.out.weights);
            for (acc, &l) in out.load.iter_mut().zip(&shard.out.load) {
                *acc += l;
            }
            start += len;
        }
    }

    /// Allocating convenience wrapper around [`Self::route_into`].
    pub fn route(&mut self, h: &[f32]) -> RouterBatch {
        let mut out = RouterBatch::new();
        self.route_into(h, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::router::synthetic_lpr_router;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    /// The determinism contract: identical outputs for every thread
    /// count, including batch sizes that do not divide evenly.
    #[test]
    fn multi_thread_matches_single_thread() {
        let mut rng = Rng::new(9);
        for metric in ["cosine", "xattn", "kl"] {
            let r = synthetic_lpr_router(metric, &mut rng, 16, 8, 6, 2);
            let plan = r.plan().clone();
            for n in [1usize, 7, 103] {
                let h = rand_vec(&mut rng, n * 16);
                let mut single = ServingEngine::new(plan.clone(), 1);
                let want = single.route(&h);
                for threads in [2usize, 3, 4, 8] {
                    let mut eng =
                        ServingEngine::new(plan.clone(), threads);
                    let got = eng.route(&h);
                    assert_eq!(
                        got, want,
                        "{metric}: n={n} threads={threads} diverged"
                    );
                }
            }
        }
    }

    #[test]
    fn engine_matches_plan_forward() {
        let mut rng = Rng::new(21);
        let r = synthetic_lpr_router("gaussian", &mut rng, 16, 8, 6, 2);
        let plan = r.plan().clone();
        let h = rand_vec(&mut rng, 64 * 16);
        let want = plan.forward(&h);
        let mut eng = ServingEngine::new(plan, 4);
        assert_eq!(eng.route(&h), want);
    }

    #[test]
    fn load_conserved_across_shards() {
        let mut rng = Rng::new(33);
        let r = synthetic_lpr_router("dot", &mut rng, 16, 8, 6, 3);
        let mut eng = ServingEngine::new(r.plan().clone(), 3);
        let h = rand_vec(&mut rng, 50 * 16);
        let out = eng.route(&h);
        let total: f32 = out.load.iter().sum();
        assert_eq!(total as usize, 50 * 3);
        assert_eq!(out.topk_idx.len(), 50 * 3);
        assert_eq!(out.weights.len(), 50 * 3);
    }
}
