//! Minimal dense linear algebra for the serving-path router.
//!
//! Row-major f32 throughout. `matmul` is written as an i-k-j loop with a
//! flat accumulator row so the inner loop auto-vectorizes (this is the
//! routing hot path; the FFN hot loop lives in [`crate::kernels`] — see
//! `docs/ARCHITECTURE.md` and the ROADMAP perf-trajectory section for
//! how the two are tracked).

/// C[n,p] = A[n,m] @ B[m,p]
pub fn matmul(a: &[f32], b: &[f32], n: usize, m: usize, p: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; n * p];
    matmul_into(a, b, &mut c, n, m, p);
    c
}

/// C[n,p] = A[n,m] @ B[m,p], written into a caller-owned buffer
/// (overwrites `c`; the serving hot path reuses one buffer per batch).
pub fn matmul_into(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    n: usize,
    m: usize,
    p: usize,
) {
    assert_eq!(a.len(), n * m, "A shape");
    assert_eq!(b.len(), m * p, "B shape");
    assert_eq!(c.len(), n * p, "C shape");
    c.fill(0.0);
    for i in 0..n {
        let a_row = &a[i * m..(i + 1) * m];
        let c_row = &mut c[i * p..(i + 1) * p];
        for (k, &aik) in a_row.iter().enumerate() {
            let b_row = &b[k * p..(k + 1) * p];
            for (cj, &bkj) in c_row.iter_mut().zip(b_row) {
                *cj += aik * bkj;
            }
        }
    }
}

/// Per-row RMSNorm with learned scale `w` (`[d]`).
pub fn rms_norm_rows(x: &[f32], w: &[f32], n: usize, d: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n * d];
    rms_norm_rows_into(x, w, &mut out, n, d);
    out
}

/// Per-row RMSNorm into a caller-owned buffer (overwrites `out`).
pub fn rms_norm_rows_into(
    x: &[f32],
    w: &[f32],
    out: &mut [f32],
    n: usize,
    d: usize,
) {
    assert_eq!(x.len(), n * d);
    assert_eq!(w.len(), d);
    assert_eq!(out.len(), n * d);
    for i in 0..n {
        let row = &x[i * d..(i + 1) * d];
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + 1e-6).sqrt();
        for j in 0..d {
            out[i * d + j] = row[j] * inv * w[j];
        }
    }
}

/// In-place SiLU.
pub fn silu(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = *v / (1.0 + (-*v).exp());
    }
}

/// Softmax over each row of [n, d].
///
/// Max-folded for stability, seeded with `NEG_INFINITY` (a `f32::MIN`
/// seed silently corrupts rows whose entries are all below it, and an
/// all-`-inf` row — every logit masked — used to collapse to `z = 0`
/// and emit NaNs). A row with no finite maximum degrades to the uniform
/// distribution instead, matching the convention that a fully-masked
/// row carries no preference.
pub fn softmax_rows(x: &mut [f32], n: usize, d: usize) {
    for i in 0..n {
        let row = &mut x[i * d..(i + 1) * d];
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        if !m.is_finite() {
            row.fill(1.0 / d as f32);
            continue;
        }
        let mut z = 0.0;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            z += *v;
        }
        for v in row.iter_mut() {
            *v /= z;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let eye = vec![1.0, 0.0, 0.0, 1.0];
        assert_eq!(matmul(&a, &eye, 2, 2, 2), a);
    }

    #[test]
    fn matmul_known() {
        // [[1,2],[3,4]] @ [[5],[6]] = [[17],[39]]
        let c = matmul(&[1., 2., 3., 4.], &[5., 6.], 2, 2, 1);
        assert_eq!(c, vec![17.0, 39.0]);
    }

    #[test]
    fn into_variants_match_allocating_and_overwrite() {
        let a = vec![1., 2., 3., 4., 5., 6.];
        let b = vec![7., 8., 9., 10., 11., 12.];
        let mut c = vec![9.9f32; 4]; // stale garbage must be overwritten
        matmul_into(&a, &b, &mut c, 2, 3, 2);
        assert_eq!(c, matmul(&a, &b, 2, 3, 2));
        let w = vec![1.0, 0.5, 2.0];
        let mut o = vec![-3.0f32; 6];
        rms_norm_rows_into(&a, &w, &mut o, 2, 3);
        assert_eq!(o, rms_norm_rows(&a, &w, 2, 3));
    }

    #[test]
    fn rms_norm_unit_rows() {
        let x = vec![3.0, 4.0];
        let out = rms_norm_rows(&x, &[1.0, 1.0], 1, 2);
        let ms: f32 = out.iter().map(|v| v * v).sum::<f32>() / 2.0;
        assert!((ms - 1.0).abs() < 1e-4);
    }

    #[test]
    fn silu_known_points() {
        let mut x = vec![0.0, 100.0];
        silu(&mut x);
        assert!(x[0].abs() < 1e-7);
        assert!((x[1] - 100.0).abs() < 1e-3);
    }

    #[test]
    fn softmax_rows_normalize() {
        let mut x = vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0];
        softmax_rows(&mut x, 2, 3);
        for i in 0..2 {
            let s: f32 = x[i * 3..(i + 1) * 3].iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
        assert!(x[2] > x[1] && x[1] > x[0]);
    }

    /// Regression: an all-`-inf` row (every logit masked) must yield a
    /// uniform distribution, not NaNs — and rows below the old
    /// `f32::MIN` seed must still softmax correctly.
    #[test]
    fn softmax_rows_handles_masked_and_tiny_rows() {
        let inf = f32::NEG_INFINITY;
        // row 0: fully masked; row 1: ordinary logits; row 2: all
        // entries below f32::MIN's magnitude would be impossible for
        // finite f32, so use -inf mixed with a finite entry instead —
        // the finite max must win and the masked lanes must get 0.
        let mut x = vec![inf, inf, inf, 1.0, 2.0, 3.0, inf, 0.0, inf];
        softmax_rows(&mut x, 3, 3);
        for &v in &x {
            assert!(v.is_finite(), "softmax emitted a non-finite gate");
        }
        for i in 0..3 {
            let s: f32 = x[i * 3..(i + 1) * 3].iter().sum();
            assert!((s - 1.0).abs() < 1e-6, "row {i} sums to {s}");
        }
        assert_eq!(&x[..3], &[1.0 / 3.0; 3], "masked row must be uniform");
        assert_eq!(&x[6..], &[0.0, 1.0, 0.0], "masked lanes must be 0");
    }
}
