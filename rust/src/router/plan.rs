//! Compiled routing engine: `RouterConfig + RouterParams` compiled once
//! into an immutable [`RouterPlan`].
//!
//! The legacy `Router::forward` path redid per-call work on every batch:
//! it cloned and unit-ball-reprojected all `E` prototype vectors, string-
//! matched the metric name, recomputed prototype-side constants
//! (norms, `exp(±logvar)`, cross-attention keys) for every token, ran a
//! full `O(E log E)` sort per token, and allocated `Vec<Vec<_>>` outputs.
//! A `RouterPlan` hoists all of that to construction time:
//!
//! - prototypes are unit-ball projected **once** (`project_unit_ball`);
//! - the metric string compiles to a [`ScoreKernel`] enum, selected once;
//! - per-prototype constants are precomputed per kernel: `‖p‖+eps`
//!   (cosine), `exp(-logvar)` inverse variances (Mahalanobis),
//!   `exp(logvar)` / `sqrt` thereof (Wasserstein/KL/JS/Hellinger),
//!   cross-attention keys `K = p @ w_k` (xattn), `2σ²` (gaussian);
//! - [`RouterPlan::forward_into`] routes into a caller-owned
//!   [`RouterBatch`] using a reusable [`RouteBuffers`] arena — zero
//!   steady-state allocation;
//! - outputs use a flat `[N*k]` layout instead of `Vec<Vec<_>>`, so the
//!   top-k ids feed `dispatch::DispatchSim::step` directly;
//! - selection is an `O(E·k)` partial insertion-select
//!   ([`select_topk`]) instead of a full sort, with tie-breaking
//!   bit-identical to the legacy path (pinned by the goldens and by the
//!   `plan_matches_legacy_router_exactly` property test below).
//!
//! Every float operation is kept in the same order as the legacy
//! implementation so plan outputs are *bit-identical* on indices and
//! float-equal on weights/load — precomputation only moves work, it
//! never reassociates it.

use super::linalg::{matmul_into, rms_norm_rows_into, silu};
use super::{
    project_unit_ball, rank_cmp, RouterConfig, RouterKind, RouterOutput,
    RouterParams, EPS,
};
use std::cmp::Ordering;

/// The §2.4.1 metric library as a fused-kernel enum: parsed once at plan
/// build instead of string-matched per batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScoreKernel {
    Dot,
    Cosine,
    Gaussian,
    Mahalanobis,
    Xattn,
    Wasserstein,
    Kl,
    Js,
    Hellinger,
}

impl ScoreKernel {
    pub fn parse(metric: &str) -> Option<ScoreKernel> {
        Some(match metric {
            "dot" => ScoreKernel::Dot,
            "cosine" => ScoreKernel::Cosine,
            "gaussian" => ScoreKernel::Gaussian,
            "mahalanobis" => ScoreKernel::Mahalanobis,
            "xattn" => ScoreKernel::Xattn,
            "wasserstein" => ScoreKernel::Wasserstein,
            "kl" => ScoreKernel::Kl,
            "js" => ScoreKernel::Js,
            "hellinger" => ScoreKernel::Hellinger,
            _ => return None,
        })
    }

    /// Kernels that read the token-side log-variance head.
    pub fn needs_logvar(self) -> bool {
        matches!(
            self,
            ScoreKernel::Wasserstein
                | ScoreKernel::Kl
                | ScoreKernel::Js
                | ScoreKernel::Hellinger
        )
    }

    /// Kernels that additionally need per-dim standard deviations.
    pub fn needs_std(self) -> bool {
        matches!(self, ScoreKernel::Wasserstein | ScoreKernel::Hellinger)
    }
}

/// Flat routing result for one batch: `[N*k]` ids/weights plus the `[E]`
/// load histogram. The id buffer is directly consumable by
/// `dispatch::DispatchSim::step` (one entry per (token, slot) pair).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RouterBatch {
    pub n: usize,
    pub top_k: usize,
    /// `[N*k]` expert ids, per-token descending score order
    /// (NaN loses, ties -> lower id).
    pub topk_idx: Vec<u32>,
    /// `[N*k]` combine weights, same layout.
    pub weights: Vec<f32>,
    /// `[E]` assignment counts.
    pub load: Vec<f32>,
}

impl RouterBatch {
    pub fn new() -> RouterBatch {
        RouterBatch::default()
    }

    /// Resize for a batch of `n` tokens (clears contents; reuses the
    /// existing capacity, so steady-state calls do not allocate).
    pub fn reset(&mut self, n: usize, k: usize, e: usize) {
        self.n = n;
        self.top_k = k;
        self.topk_idx.clear();
        self.topk_idx.resize(n * k, 0);
        self.weights.clear();
        self.weights.resize(n * k, 0.0);
        self.load.clear();
        self.load.resize(e, 0.0);
    }

    pub fn idx_row(&self, r: usize) -> &[u32] {
        &self.topk_idx[r * self.top_k..(r + 1) * self.top_k]
    }

    pub fn weight_row(&self, r: usize) -> &[f32] {
        &self.weights[r * self.top_k..(r + 1) * self.top_k]
    }

    /// Convert to the legacy nested-`Vec` output (compat shim for code
    /// that still wants `Vec<Vec<_>>` rows).
    pub fn into_nested(self) -> RouterOutput {
        let k = self.top_k;
        RouterOutput {
            topk_idx: self
                .topk_idx
                .chunks(k.max(1))
                .map(|c| c.to_vec())
                .collect(),
            weights: self
                .weights
                .chunks(k.max(1))
                .map(|c| c.to_vec())
                .collect(),
            load: self.load,
        }
    }
}

/// Reusable scratch arena for [`RouterPlan::forward_into`]. All buffers
/// grow to the high-water batch size once and are reused afterwards.
#[derive(Debug, Clone, Default)]
pub struct RouteBuffers {
    a: Vec<f32>,      // [n, d]  SiLU(RMSNorm(h))
    mu: Vec<f32>,     // [n, dz] latent means
    lv: Vec<f32>,     // [n, dz] latent log-variances (variance kernels)
    v1: Vec<f32>,     // [n, dz] exp(lv)
    s1: Vec<f32>,     // [n, dz] sqrt(exp(lv))
    zn: Vec<f32>,     // [n]     token latent norms (cosine)
    q: Vec<f32>,      // [n, H*dh] cross-attention queries
    scores: Vec<f32>, // [n, E]
    sel: Vec<f32>,    // [E]     DeepSeek biased selection scores
    top: Vec<(f32, u32)>, // [k] partial-select scratch
}

impl RouteBuffers {
    pub fn new() -> RouteBuffers {
        RouteBuffers::default()
    }
}

/// Indices of the k best scores (NaN loses, ties -> lower index),
/// best-first, via a single `O(E·k)` insertion pass over the row —
/// replaces the legacy full `O(E log E)` sort. Order is identical to
/// `top_k_indices` by construction (both order by [`rank_cmp`]).
pub fn select_topk(row: &[f32], k: usize, top: &mut Vec<(f32, u32)>) {
    top.clear();
    let k = k.min(row.len());
    if k == 0 {
        return;
    }
    for (i, &s) in row.iter().enumerate() {
        let i = i as u32;
        if top.len() == k {
            let worst = top[k - 1];
            if rank_cmp(s, i, worst.0, worst.1) != Ordering::Less {
                continue;
            }
            top.pop();
        }
        let mut pos = top.len();
        while pos > 0
            && rank_cmp(s, i, top[pos - 1].0, top[pos - 1].1)
                == Ordering::Less
        {
            pos -= 1;
        }
        top.insert(pos, (s, i));
    }
}

/// An immutable, pre-compiled router: all per-call invariants of the
/// legacy `Router` hoisted to construction time. Cheap to share across
/// threads (`Sync`); see `router::engine::ServingEngine` for the
/// parallel sharded serving path.
#[derive(Debug, Clone)]
pub struct RouterPlan {
    pub cfg: RouterConfig,
    kernel: Option<ScoreKernel>,
    // vanilla / deepseek
    wg: Vec<f32>,
    bias: Vec<f32>,
    // lpr encoder
    norm: Vec<f32>,
    w_mu: Vec<f32>,
    b_mu: Vec<f32>,
    w_lv: Vec<f32>,
    b_lv: Vec<f32>,
    // prototypes, unit-ball projected once at build
    proto_mu: Vec<f32>,
    // per-kernel prototype-side precomputes (empty when unused)
    proto_norm: Vec<f32>, // [E]     ‖p‖ + eps            (cosine)
    proto_iv: Vec<f32>,   // [E, dz] exp(-logvar)          (mahalanobis)
    proto_var: Vec<f32>,  // [E, dz] exp(logvar)           (divergences)
    proto_sd: Vec<f32>,   // [E, dz] sqrt(exp(logvar))     (wass/hellinger)
    proto_k: Vec<f32>,    // [E, H*dh] keys p @ w_k        (xattn)
    wq: Vec<f32>,         // [H, dz, dh]                   (xattn)
    dh: usize,
    sqrt_dh: f32,
    gauss_denom: f32, // 2σ²
}

impl RouterPlan {
    /// Compile a plan from raw (unprojected) parameters; applies the
    /// unit-ball projection internally when the config asks for it.
    pub fn new(cfg: RouterConfig, p: &RouterParams) -> RouterPlan {
        let mut p = p.clone();
        if cfg.unit_ball {
            project_unit_ball(&mut p.proto_mu, cfg.latent_dim);
        }
        RouterPlan::from_projected(cfg, &p)
    }

    /// Compile from parameters whose prototypes are **already**
    /// unit-ball projected (the `Router` constructor projects at build,
    /// so its lazily-built plan must not re-project — re-projection is
    /// not bit-stable for rows that renormalize to slightly above 1).
    pub(crate) fn from_projected(
        cfg: RouterConfig,
        p: &RouterParams,
    ) -> RouterPlan {
        // with k > E the flat [N*k] layout would silently pad rows with
        // expert 0 — fail at build time instead
        assert!(
            cfg.top_k <= cfg.n_experts,
            "top_k ({}) must not exceed n_experts ({})",
            cfg.top_k,
            cfg.n_experts
        );
        let (dz, e, heads) = (cfg.latent_dim, cfg.n_experts, cfg.n_score_heads);
        let kernel = match cfg.kind {
            RouterKind::Lpr => Some(
                ScoreKernel::parse(&cfg.metric).unwrap_or_else(|| {
                    panic!("unknown metric '{}'", cfg.metric)
                }),
            ),
            _ => None,
        };
        let mut plan = RouterPlan {
            kernel,
            wg: Vec::new(),
            bias: Vec::new(),
            norm: Vec::new(),
            w_mu: Vec::new(),
            b_mu: Vec::new(),
            w_lv: Vec::new(),
            b_lv: Vec::new(),
            proto_mu: Vec::new(),
            proto_norm: Vec::new(),
            proto_iv: Vec::new(),
            proto_var: Vec::new(),
            proto_sd: Vec::new(),
            proto_k: Vec::new(),
            wq: Vec::new(),
            dh: 0,
            sqrt_dh: 1.0,
            gauss_denom: 1.0,
            cfg,
        };
        match plan.cfg.kind {
            RouterKind::Vanilla => plan.wg = p.wg.clone(),
            RouterKind::DeepSeek => {
                plan.wg = p.wg.clone();
                plan.bias = p.bias.clone();
            }
            RouterKind::Lpr => {
                plan.norm = p.norm.clone();
                plan.w_mu = p.w_mu.clone();
                plan.b_mu = p.b_mu.clone();
                plan.w_lv = p.w_lv.clone();
                plan.b_lv = p.b_lv.clone();
                plan.proto_mu = p.proto_mu.clone();
            }
        }
        match kernel {
            Some(ScoreKernel::Cosine) => {
                plan.proto_norm = (0..e)
                    .map(|i| {
                        plan.proto_mu[i * dz..(i + 1) * dz]
                            .iter()
                            .map(|x| x * x)
                            .sum::<f32>()
                            .sqrt()
                            + EPS
                    })
                    .collect();
            }
            Some(ScoreKernel::Gaussian) => {
                let s = plan.cfg.gaussian_sigma;
                plan.gauss_denom = 2.0 * s * s;
            }
            Some(ScoreKernel::Mahalanobis) => {
                plan.proto_iv =
                    p.proto_lv.iter().map(|x| (-x).exp()).collect();
            }
            Some(ScoreKernel::Xattn) => {
                let dh = dz.div_euclid(heads).max(1);
                plan.dh = dh;
                plan.sqrt_dh = (dh as f32).sqrt();
                plan.wq = p.wq.clone();
                // keys K[i, h, c] = Σ_j p[i,j] · w_k[h, j, c], summed in
                // the same j-ascending order as the legacy per-token loop
                let mut pk = vec![0.0f32; e * heads * dh];
                for i in 0..e {
                    for hh in 0..heads {
                        for c in 0..dh {
                            let mut acc = 0.0f32;
                            for j in 0..dz {
                                acc += plan.proto_mu[i * dz + j]
                                    * p.wk[hh * dz * dh + j * dh + c];
                            }
                            pk[i * heads * dh + hh * dh + c] = acc;
                        }
                    }
                }
                plan.proto_k = pk;
            }
            Some(k) if k.needs_logvar() => {
                plan.proto_var =
                    p.proto_lv.iter().map(|x| x.exp()).collect();
                if k.needs_std() {
                    plan.proto_sd =
                        plan.proto_var.iter().map(|x| x.sqrt()).collect();
                }
            }
            _ => {}
        }
        plan
    }

    /// Route a batch of token activations `h` ([N, d] row-major) into
    /// caller-owned output + scratch. Deterministic; zero steady-state
    /// allocation once the buffers have grown to the batch size.
    pub fn forward_into(
        &self,
        h: &[f32],
        buf: &mut RouteBuffers,
        out: &mut RouterBatch,
    ) {
        let d = self.cfg.d_model;
        assert_eq!(h.len() % d, 0, "h must be [N, {d}]");
        let n = h.len() / d;
        out.reset(n, self.cfg.top_k, self.cfg.n_experts);
        self.scores_into(h, n, buf);
        match self.cfg.kind {
            RouterKind::Vanilla | RouterKind::Lpr => {
                self.select_softmax(n, buf, out)
            }
            RouterKind::DeepSeek => self.select_deepseek(n, buf, out),
        }
    }

    /// Allocating convenience wrapper around [`Self::forward_into`].
    pub fn forward(&self, h: &[f32]) -> RouterBatch {
        let mut buf = RouteBuffers::new();
        let mut out = RouterBatch::new();
        self.forward_into(h, &mut buf, &mut out);
        out
    }

    fn scores_into(&self, h: &[f32], n: usize, buf: &mut RouteBuffers) {
        let (d, e) = (self.cfg.d_model, self.cfg.n_experts);
        buf.scores.clear();
        buf.scores.resize(n * e, 0.0);
        match self.cfg.kind {
            RouterKind::Vanilla => {
                matmul_into(h, &self.wg, &mut buf.scores, n, d, e);
            }
            RouterKind::DeepSeek => {
                matmul_into(h, &self.wg, &mut buf.scores, n, d, e);
                for v in buf.scores.iter_mut() {
                    *v = 1.0 / (1.0 + (-*v).exp());
                }
            }
            RouterKind::Lpr => self.lpr_scores_into(h, n, buf),
        }
    }

    fn lpr_scores_into(&self, h: &[f32], n: usize, buf: &mut RouteBuffers) {
        let (d, dz, e) = (
            self.cfg.d_model,
            self.cfg.latent_dim,
            self.cfg.n_experts,
        );
        let kernel = self.kernel.expect("lpr plan carries a kernel");
        // encoder: a = SiLU(RMSNorm(h)); mu head (eval: z = mu)
        buf.a.clear();
        buf.a.resize(n * d, 0.0);
        rms_norm_rows_into(h, &self.norm, &mut buf.a, n, d);
        silu(&mut buf.a);
        buf.mu.clear();
        buf.mu.resize(n * dz, 0.0);
        matmul_into(&buf.a, &self.w_mu, &mut buf.mu, n, d, dz);
        for r in 0..n {
            for j in 0..dz {
                buf.mu[r * dz + j] += self.b_mu[j];
            }
        }
        // logvar head only when the kernel reads it (the legacy path
        // always computed it; skipping is score-invariant)
        if kernel.needs_logvar() {
            buf.lv.clear();
            buf.lv.resize(n * dz, 0.0);
            matmul_into(&buf.a, &self.w_lv, &mut buf.lv, n, d, dz);
            for r in 0..n {
                for j in 0..dz {
                    buf.lv[r * dz + j] = (buf.lv[r * dz + j]
                        + self.b_lv[j])
                        .clamp(-8.0, 4.0);
                }
            }
            buf.v1.clear();
            buf.v1.extend(buf.lv.iter().map(|x| x.exp()));
            if kernel.needs_std() {
                buf.s1.clear();
                buf.s1.extend(buf.v1.iter().map(|x| x.sqrt()));
            }
        }
        let mu = &buf.mu;
        let pm = &self.proto_mu;
        let scores = &mut buf.scores;
        match kernel {
            ScoreKernel::Dot => {
                for r in 0..n {
                    for i in 0..e {
                        let mut s = 0.0;
                        for j in 0..dz {
                            s += mu[r * dz + j] * pm[i * dz + j];
                        }
                        scores[r * e + i] = s;
                    }
                }
            }
            ScoreKernel::Cosine => {
                buf.zn.clear();
                buf.zn.extend((0..n).map(|r| {
                    mu[r * dz..(r + 1) * dz]
                        .iter()
                        .map(|x| x * x)
                        .sum::<f32>()
                        .sqrt()
                        + EPS
                }));
                for r in 0..n {
                    for i in 0..e {
                        let mut s = 0.0;
                        for j in 0..dz {
                            s += mu[r * dz + j] * pm[i * dz + j];
                        }
                        scores[r * e + i] =
                            s / (buf.zn[r] * self.proto_norm[i]);
                    }
                }
            }
            ScoreKernel::Gaussian => {
                for r in 0..n {
                    for i in 0..e {
                        let mut d2 = 0.0;
                        for j in 0..dz {
                            let dd = mu[r * dz + j] - pm[i * dz + j];
                            d2 += dd * dd;
                        }
                        scores[r * e + i] = (-d2 / self.gauss_denom).exp();
                    }
                }
            }
            ScoreKernel::Mahalanobis => {
                for r in 0..n {
                    for i in 0..e {
                        let mut d2 = 0.0;
                        for j in 0..dz {
                            let dd = mu[r * dz + j] - pm[i * dz + j];
                            d2 += dd * dd * self.proto_iv[i * dz + j];
                        }
                        scores[r * e + i] = -d2;
                    }
                }
            }
            ScoreKernel::Xattn => {
                let (heads, dh) = (self.cfg.n_score_heads, self.dh);
                let hd = heads * dh;
                // queries Q[r, h, c] = Σ_j z[r,j] · w_q[h, j, c]
                buf.q.clear();
                buf.q.resize(n * hd, 0.0);
                for r in 0..n {
                    for hh in 0..heads {
                        for c in 0..dh {
                            let mut acc = 0.0f32;
                            for j in 0..dz {
                                acc += mu[r * dz + j]
                                    * self.wq[hh * dz * dh + j * dh + c];
                            }
                            buf.q[r * hd + hh * dh + c] = acc;
                        }
                    }
                }
                let heads_f = heads as f32;
                for r in 0..n {
                    for i in 0..e {
                        let mut s = 0.0f32;
                        for hh in 0..heads {
                            let qb = &buf.q
                                [r * hd + hh * dh..r * hd + (hh + 1) * dh];
                            let kb = &self.proto_k
                                [i * hd + hh * dh..i * hd + (hh + 1) * dh];
                            let mut dot = 0.0f32;
                            for c in 0..dh {
                                dot += qb[c] * kb[c];
                            }
                            s += dot / self.sqrt_dh;
                        }
                        scores[r * e + i] = s / heads_f;
                    }
                }
            }
            ScoreKernel::Wasserstein => {
                for r in 0..n {
                    for i in 0..e {
                        let mut acc = 0.0f32;
                        for j in 0..dz {
                            let m1 = mu[r * dz + j];
                            let m2 = pm[i * dz + j];
                            let dm2 = (m1 - m2) * (m1 - m2);
                            let ds = buf.s1[r * dz + j]
                                - self.proto_sd[i * dz + j];
                            acc += dm2 + ds * ds;
                        }
                        scores[r * e + i] = -acc;
                    }
                }
            }
            ScoreKernel::Kl => {
                for r in 0..n {
                    for i in 0..e {
                        let mut acc = 0.0f32;
                        for j in 0..dz {
                            let m1 = mu[r * dz + j];
                            let m2 = pm[i * dz + j];
                            let v1 = buf.v1[r * dz + j];
                            let v2 = self.proto_var[i * dz + j];
                            let dm2 = (m1 - m2) * (m1 - m2);
                            acc += 0.5
                                * ((v2 / v1).ln() + (v1 + dm2) / v2 - 1.0);
                        }
                        scores[r * e + i] = -acc;
                    }
                }
            }
            ScoreKernel::Js => {
                for r in 0..n {
                    for i in 0..e {
                        let mut acc = 0.0f32;
                        for j in 0..dz {
                            let m1 = mu[r * dz + j];
                            let m2 = pm[i * dz + j];
                            let v1 = buf.v1[r * dz + j];
                            let v2 = self.proto_var[i * dz + j];
                            let v0 = 0.5 * (v1 + v2);
                            let m0 = 0.5 * (m1 + m2);
                            acc += 0.25
                                * (((v1 + v2) * (v1 + v2)
                                    / (4.0 * v1 * v2))
                                    .ln()
                                    + (v1 + (m1 - m0) * (m1 - m0)) / v0
                                    + (v2 + (m2 - m0) * (m2 - m0)) / v0
                                    - 2.0);
                        }
                        scores[r * e + i] = -acc;
                    }
                }
            }
            ScoreKernel::Hellinger => {
                for r in 0..n {
                    for i in 0..e {
                        let mut log_bc = 0.0f32;
                        for j in 0..dz {
                            let m1 = mu[r * dz + j];
                            let m2 = pm[i * dz + j];
                            let v1 = buf.v1[r * dz + j];
                            let v2 = self.proto_var[i * dz + j];
                            let s1 = buf.s1[r * dz + j];
                            let s2 = self.proto_sd[i * dz + j];
                            let dm2 = (m1 - m2) * (m1 - m2);
                            log_bc += 0.5
                                * (2.0 * s1 * s2 / (v1 + v2) + EPS).ln()
                                - 0.25 * dm2 / (v1 + v2);
                        }
                        scores[r * e + i] = -(1.0 - log_bc.exp());
                    }
                }
            }
        }
    }

    fn select_softmax(
        &self,
        n: usize,
        buf: &mut RouteBuffers,
        out: &mut RouterBatch,
    ) {
        let (e, k) = (self.cfg.n_experts, self.cfg.top_k);
        for r in 0..n {
            {
                let row = &buf.scores[r * e..(r + 1) * e];
                select_topk(row, k, &mut buf.top);
            }
            let idx_out = &mut out.topk_idx[r * k..(r + 1) * k];
            let w_out = &mut out.weights[r * k..(r + 1) * k];
            // softmax over the selected scores (paper eq.6)
            let m = buf
                .top
                .iter()
                .map(|&(s, _)| s)
                .fold(f32::MIN, f32::max);
            let mut z = 0.0f32;
            for (j, &(s, i)) in buf.top.iter().enumerate() {
                let ex = (s - m).exp();
                w_out[j] = ex;
                z += ex;
                idx_out[j] = i;
                out.load[i as usize] += 1.0;
            }
            for w in w_out.iter_mut() {
                *w /= z;
            }
        }
    }

    fn select_deepseek(
        &self,
        n: usize,
        buf: &mut RouteBuffers,
        out: &mut RouterBatch,
    ) {
        let (e, k) = (self.cfg.n_experts, self.cfg.top_k);
        for r in 0..n {
            // bias enters selection only
            buf.sel.clear();
            buf.sel.extend(
                buf.scores[r * e..(r + 1) * e]
                    .iter()
                    .zip(&self.bias)
                    .map(|(s, b)| s + b),
            );
            select_topk(&buf.sel, k, &mut buf.top);
            let row = &buf.scores[r * e..(r + 1) * e];
            let idx_out = &mut out.topk_idx[r * k..(r + 1) * k];
            let w_out = &mut out.weights[r * k..(r + 1) * k];
            let mut z = 0.0f32;
            for (j, &(_, i)) in buf.top.iter().enumerate() {
                let raw = row[i as usize];
                w_out[j] = raw;
                z += raw;
                idx_out[j] = i;
                out.load[i as usize] += 1.0;
            }
            let z = z + 1e-9;
            for w in w_out.iter_mut() {
                *w /= z;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::router::{synthetic_lpr_router, top_k_indices, Router, METRICS};
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32 * scale).collect()
    }

    fn random_router(rng: &mut Rng, kind: RouterKind, metric: &str) -> Router {
        let (d, dz, e, k) = (12, 8, 7, 3);
        match kind {
            RouterKind::Lpr => synthetic_lpr_router(metric, rng, d, dz, e, k),
            _ => {
                let cfg = RouterConfig {
                    kind: kind.clone(),
                    d_model: d,
                    n_experts: e,
                    top_k: k,
                    latent_dim: 0,
                    metric: "dot".into(),
                    unit_ball: false,
                    gaussian_sigma: 1.0,
                    n_score_heads: 1,
                };
                let p = RouterParams {
                    wg: rand_vec(rng, d * e, 0.5),
                    bias: rand_vec(rng, e, 0.3),
                    ..Default::default()
                };
                Router::new(cfg, p)
            }
        }
    }

    /// Plan outputs must be bit-identical (indices, load) and
    /// float-equal (weights) to the legacy per-call implementation,
    /// across all three router kinds and all nine metrics.
    #[test]
    fn plan_matches_legacy_router_exactly() {
        forall(
            36,
            2024,
            |rng| {
                // cases: 0 vanilla, 1 deepseek, 2..=10 one LPR metric
                let case = rng.below(2 + METRICS.len());
                let r = match case {
                    0 => random_router(rng, RouterKind::Vanilla, "dot"),
                    1 => random_router(rng, RouterKind::DeepSeek, "dot"),
                    c => random_router(rng, RouterKind::Lpr, METRICS[c - 2]),
                };
                let h = rand_vec(rng, 9 * r.cfg.d_model, 1.0);
                (r, h)
            },
            |(r, h)| {
                let legacy = r.forward_reference(h);
                let flat = r.plan().forward(h);
                let nested = flat.into_nested();
                if nested.topk_idx != legacy.topk_idx {
                    return Err(format!(
                        "{}: indices diverge: {:?} vs {:?}",
                        r.cfg.metric, nested.topk_idx, legacy.topk_idx
                    ));
                }
                if nested.weights != legacy.weights {
                    return Err(format!(
                        "{}: weights diverge",
                        r.cfg.metric
                    ));
                }
                if nested.load != legacy.load {
                    return Err(format!("{}: load diverges", r.cfg.metric));
                }
                Ok(())
            },
        );
    }

    /// The partial insertion-select must order exactly like the legacy
    /// full sort, including NaN demotion and index tie-breaks.
    #[test]
    fn select_topk_matches_full_sort() {
        forall(
            200,
            7,
            |rng| {
                let e = 1 + rng.below(24);
                let k = 1 + rng.below(e.min(9));
                let row: Vec<f32> = (0..e)
                    .map(|_| match rng.below(6) {
                        0 => f32::NAN,
                        1 => 0.5, // force score ties
                        _ => rng.normal() as f32,
                    })
                    .collect();
                (row, k)
            },
            |(row, k)| {
                let mut top = Vec::new();
                select_topk(row, *k, &mut top);
                let got: Vec<u32> = top.iter().map(|&(_, i)| i).collect();
                let want = top_k_indices(row, *k);
                if got != want {
                    return Err(format!("{got:?} != {want:?}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn forward_into_reuses_buffers_and_resets_output() {
        let mut rng = Rng::new(3);
        let r = synthetic_lpr_router("cosine", &mut rng, 16, 8, 6, 2);
        let plan = r.plan().clone();
        let mut buf = RouteBuffers::new();
        let mut out = RouterBatch::new();
        let h1 = rand_vec(&mut rng, 32 * 16, 1.0);
        plan.forward_into(&h1, &mut buf, &mut out);
        let first = out.clone();
        // a second, smaller batch must fully overwrite the outputs
        let h2 = rand_vec(&mut rng, 8 * 16, 1.0);
        plan.forward_into(&h2, &mut buf, &mut out);
        assert_eq!(out.n, 8);
        assert_eq!(out.topk_idx.len(), 8 * 2);
        let total: f32 = out.load.iter().sum();
        assert_eq!(total as usize, 8 * 2);
        // and routing h1 again reproduces the first result exactly
        plan.forward_into(&h1, &mut buf, &mut out);
        assert_eq!(out, first);
    }

    #[test]
    fn kernel_parse_covers_metric_library() {
        for m in METRICS {
            assert!(ScoreKernel::parse(m).is_some(), "metric {m}");
        }
        assert!(ScoreKernel::parse("euclidean-typo").is_none());
    }

    #[test]
    #[should_panic(expected = "unknown metric")]
    fn unknown_metric_panics_at_plan_build() {
        let mut rng = Rng::new(5);
        let mut r = synthetic_lpr_router("cosine", &mut rng, 8, 4, 4, 2);
        r.cfg.metric = "nope".into();
        let _ = RouterPlan::new(r.cfg.clone(), &r.p);
    }
}
