//! Pure-Rust router forward pass — the serving-path twin of
//! `python/compile/routers.py`.
//!
//! Used by (a) the dispatch simulator, which needs millions of routing
//! decisions per second without a PJRT round-trip, and (b) the parity
//! tests in `rust/tests/goldens.rs`, which pin this implementation
//! bit-for-bit (top-k indices) and to float tolerance (weights) against
//! the JAX reference through `artifacts/goldens/*.json`.
//!
//! Implements all three router families (vanilla top-k softmax, DeepSeek
//! aux-free sigmoid+bias, LPR) and the full §2.4.1 metric library.
//!
//! # Architecture: compiled plans + serving engine
//!
//! The serving hot path is a two-stage compile-then-route design:
//!
//! - [`plan::RouterPlan`] — `RouterConfig + RouterParams` compiled once
//!   into an immutable plan: unit-ball-projected prototypes, a fused
//!   [`plan::ScoreKernel`] selected once (no per-batch string match),
//!   and precomputed prototype-side constants (norms, inverse
//!   variances, cross-attention keys). `RouterPlan::forward_into`
//!   routes into flat `[N*k]` buffers ([`plan::RouterBatch`]) with a
//!   reusable [`plan::RouteBuffers`] arena — zero steady-state
//!   allocation — and an `O(E·k)` partial select instead of a full
//!   per-token sort.
//! - [`engine::ServingEngine`] — shards batches across scoped worker
//!   threads (spawned per batch; per-shard buffers persist) with merged
//!   load accounting. Outputs are bit-identical for every thread count
//!   (see the module docs for the determinism contract).
//!   `ServingEngine::forward_full` extends the path end to end: the
//!   routed batch compiles into a capacity-binned
//!   `dispatch::DispatchPlan` (overflow policy applied at build), real
//!   expert FFNs (`experts::ExpertBank`) run over the grouped layout,
//!   and gate-weighted outputs combine back into token order — same
//!   determinism contract.
//! - [`Router`] — the legacy façade. `Router::forward` is a thin
//!   compatibility wrapper over a lazily-built plan;
//!   `Router::forward_reference` keeps the original per-call
//!   implementation as the parity oracle for tests. Prototypes are
//!   projected **once at construction** (mutating `p` after the first
//!   `forward` will not rebuild the cached plan).
//!
//! Selection order everywhere: descending score, NaN always loses,
//! score ties break to the lower expert id ([`rank_cmp`] is the single
//! source of truth, matching `jax.lax.top_k` on NaN-free input).

pub mod engine;
pub mod linalg;
pub mod plan;

pub use engine::{FullForward, ServingEngine};
pub use plan::{RouteBuffers, RouterBatch, RouterPlan, ScoreKernel};

use crate::util::json::Json;
use crate::util::rng::Rng;
use linalg::{matmul, rms_norm_rows, silu};
use std::cmp::Ordering;
use std::sync::OnceLock;

pub const METRICS: &[&str] = &[
    "dot", "cosine", "gaussian", "mahalanobis", "xattn", "wasserstein",
    "kl", "js", "hellinger",
];

pub(crate) const EPS: f32 = 1e-6;

/// Unit-ball projection of `[E, dz]` prototype rows, in place: rows with
/// norm > 1 are rescaled onto the ball. Applied exactly once per
/// parameter set (at `Router`/`RouterPlan` construction) — the
/// projection is not bit-stable under repetition for rows that
/// renormalize to slightly above 1.
pub(crate) fn project_unit_ball(pm: &mut [f32], dz: usize) {
    if dz == 0 {
        return;
    }
    for row in pm.chunks_mut(dz) {
        let norm: f32 = row.iter().map(|x| x * x).sum::<f32>().sqrt();
        if norm > 1.0 {
            row.iter_mut().for_each(|x| *x /= norm);
        }
    }
}

/// Total selection order shared by the legacy sort and the plan's
/// partial select: `Less` means "(sa, a) ranks before (sb, b)".
/// Descending score; NaN scores lose deterministically (all non-NaN
/// scores rank first); ties — including NaN/NaN — break to the lower
/// index.
pub fn rank_cmp(sa: f32, a: u32, sb: f32, b: u32) -> Ordering {
    match (sa.is_nan(), sb.is_nan()) {
        (false, true) => Ordering::Less,
        (true, false) => Ordering::Greater,
        (true, true) => a.cmp(&b),
        (false, false) => sb
            .partial_cmp(&sa)
            .expect("non-NaN scores are comparable")
            .then(a.cmp(&b)),
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum RouterKind {
    Vanilla,
    DeepSeek,
    Lpr,
}

/// Flat router parameters (layout documented per field).
#[derive(Debug, Clone, Default)]
pub struct RouterParams {
    // vanilla / deepseek
    pub wg: Vec<f32>,   // [d, E] row-major
    pub bias: Vec<f32>, // [E] (deepseek selection bias)
    // lpr
    pub norm: Vec<f32>,     // [d]
    pub w_mu: Vec<f32>,     // [d, dz]
    pub b_mu: Vec<f32>,     // [dz]
    pub w_lv: Vec<f32>,     // [d, dz]
    pub b_lv: Vec<f32>,     // [dz]
    pub proto_mu: Vec<f32>, // [E, dz]
    pub proto_lv: Vec<f32>, // [E, dz]
    pub wq: Vec<f32>,       // [H, dz, dh] (xattn only)
    pub wk: Vec<f32>,       // [H, dz, dh]
}

#[derive(Debug, Clone)]
pub struct RouterConfig {
    pub kind: RouterKind,
    pub d_model: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub latent_dim: usize,
    pub metric: String,
    pub unit_ball: bool,
    pub gaussian_sigma: f32,
    pub n_score_heads: usize,
}

#[derive(Debug, Clone)]
pub struct RouterOutput {
    /// `[N, k]` expert ids, descending score order (ties -> lower id).
    pub topk_idx: Vec<Vec<u32>>,
    /// `[N, k]` combine weights.
    pub weights: Vec<Vec<f32>>,
    /// `[E]` assignment counts.
    pub load: Vec<f32>,
}

#[derive(Debug, Clone)]
pub struct Router {
    pub cfg: RouterConfig,
    /// NOTE: prototypes are unit-ball projected at construction; the
    /// first `forward` caches a compiled plan, so mutations of `p`
    /// after that are not observed by `forward` (rebuild the router).
    pub p: RouterParams,
    /// `OnceLock` (not `OnceCell`) so `Router` stays `Sync` — sharing
    /// a router across threads was legal before this field existed.
    compiled: OnceLock<RouterPlan>,
}

impl Router {
    pub fn new(cfg: RouterConfig, mut p: RouterParams) -> Self {
        // project once at construction instead of cloning + reprojecting
        // all prototypes on every forward call
        if cfg.kind == RouterKind::Lpr && cfg.unit_ball {
            project_unit_ball(&mut p.proto_mu, cfg.latent_dim);
        }
        Router { cfg, p, compiled: OnceLock::new() }
    }

    /// The compiled plan for this router, built lazily on first use.
    pub fn plan(&self) -> &RouterPlan {
        self.compiled.get_or_init(|| {
            RouterPlan::from_projected(self.cfg.clone(), &self.p)
        })
    }

    /// Route a batch of token activations `h` ([N, d] row-major).
    /// Deterministic (eval-mode: mean latents, no reparam noise).
    ///
    /// Compatibility wrapper: routes through the lazily-built
    /// [`RouterPlan`] and converts the flat output to the legacy nested
    /// layout. Hot paths should use [`Router::plan`] /
    /// [`RouterPlan::forward_into`], or the engine facade
    /// (`lpr::engine::Engine::builder()` + `MoeEngine::route_into`).
    #[deprecated(
        note = "route through Router::plan()/RouterPlan::forward_into, \
                or the engine facade (Engine::builder() + \
                MoeEngine::route_into)"
    )]
    pub fn forward(&self, h: &[f32]) -> RouterOutput {
        self.plan().forward(h).into_nested()
    }

    /// The original per-call implementation, kept as the bit-parity
    /// oracle for the plan path (see `plan_matches_legacy_router_exactly`
    /// and `rust/tests/goldens.rs`).
    pub fn forward_reference(&self, h: &[f32]) -> RouterOutput {
        let d = self.cfg.d_model;
        assert_eq!(h.len() % d, 0, "h must be [N, {d}]");
        let n = h.len() / d;
        let scores = self.scores(h, n);
        match self.cfg.kind {
            RouterKind::Vanilla | RouterKind::Lpr => {
                self.topk_softmax(&scores, n)
            }
            RouterKind::DeepSeek => self.deepseek_select(&scores, n),
        }
    }

    /// Raw [N, E] scores.
    pub fn scores(&self, h: &[f32], n: usize) -> Vec<f32> {
        let (d, e) = (self.cfg.d_model, self.cfg.n_experts);
        match self.cfg.kind {
            RouterKind::Vanilla => matmul(h, &self.p.wg, n, d, e),
            RouterKind::DeepSeek => {
                let mut s = matmul(h, &self.p.wg, n, d, e);
                for v in s.iter_mut() {
                    *v = 1.0 / (1.0 + (-*v).exp()); // sigmoid affinity
                }
                s
            }
            RouterKind::Lpr => self.lpr_scores(h, n),
        }
    }

    fn lpr_scores(&self, h: &[f32], n: usize) -> Vec<f32> {
        let (d, dz, e) = (
            self.cfg.d_model,
            self.cfg.latent_dim,
            self.cfg.n_experts,
        );
        // encoder: a = SiLU(RMSNorm(h)); mu/logvar heads (eval: z = mu)
        let mut a = rms_norm_rows(h, &self.p.norm, n, d);
        silu(&mut a);
        let mut mu = matmul(&a, &self.p.w_mu, n, d, dz);
        for r in 0..n {
            for j in 0..dz {
                mu[r * dz + j] += self.p.b_mu[j];
            }
        }
        let mut lv = matmul(&a, &self.p.w_lv, n, d, dz);
        for r in 0..n {
            for j in 0..dz {
                lv[r * dz + j] =
                    (lv[r * dz + j] + self.p.b_lv[j]).clamp(-8.0, 4.0);
            }
        }
        // prototypes were unit-ball projected once at construction
        metric_scores(
            &self.cfg.metric,
            &mu,
            &lv,
            &self.p.proto_mu,
            &self.p.proto_lv,
            &self.p.wq,
            &self.p.wk,
            n,
            e,
            dz,
            self.cfg.n_score_heads,
            self.cfg.gaussian_sigma,
        )
    }

    fn topk_softmax(&self, scores: &[f32], n: usize) -> RouterOutput {
        let (e, k) = (self.cfg.n_experts, self.cfg.top_k);
        let mut topk_idx = Vec::with_capacity(n);
        let mut weights = Vec::with_capacity(n);
        let mut load = vec![0.0f32; e];
        for r in 0..n {
            let row = &scores[r * e..(r + 1) * e];
            let idx = top_k_indices(row, k);
            // softmax over the selected scores (paper eq.6)
            let m = idx.iter().map(|&i| row[i as usize]).fold(f32::MIN, f32::max);
            let exps: Vec<f32> =
                idx.iter().map(|&i| (row[i as usize] - m).exp()).collect();
            let z: f32 = exps.iter().sum();
            for &i in &idx {
                load[i as usize] += 1.0;
            }
            weights.push(exps.iter().map(|x| x / z).collect());
            topk_idx.push(idx);
        }
        RouterOutput { topk_idx, weights, load }
    }

    fn deepseek_select(&self, affinity: &[f32], n: usize) -> RouterOutput {
        let (e, k) = (self.cfg.n_experts, self.cfg.top_k);
        let mut topk_idx = Vec::with_capacity(n);
        let mut weights = Vec::with_capacity(n);
        let mut load = vec![0.0f32; e];
        for r in 0..n {
            let row = &affinity[r * e..(r + 1) * e];
            // bias enters selection only
            let sel: Vec<f32> = row
                .iter()
                .zip(&self.p.bias)
                .map(|(s, b)| s + b)
                .collect();
            let idx = top_k_indices(&sel, k);
            let raw: Vec<f32> = idx.iter().map(|&i| row[i as usize]).collect();
            let z: f32 = raw.iter().sum::<f32>() + 1e-9;
            for &i in &idx {
                load[i as usize] += 1.0;
            }
            weights.push(raw.iter().map(|x| x / z).collect());
            topk_idx.push(idx);
        }
        RouterOutput { topk_idx, weights, load }
    }
}

/// Indices of the k largest values, descending, ties -> lower index
/// (matches `jax.lax.top_k` on NaN-free input). NaN scores lose
/// deterministically: they rank after every real score, lower index
/// first — the previous `partial_cmp(..).unwrap_or(Equal)` comparator
/// was not a total order under NaN and silently produced
/// permutation-dependent results.
pub fn top_k_indices(row: &[f32], k: usize) -> Vec<u32> {
    let mut idx: Vec<u32> = (0..row.len() as u32).collect();
    idx.sort_by(|&a, &b| rank_cmp(row[a as usize], a, row[b as usize], b));
    idx.truncate(k);
    idx
}

/// Deterministic synthetic LPR router with hypersphere-initialized
/// prototypes (the paper's §2.4 init) — the shared builder behind the
/// benches, examples, `route --synthetic`, `dispatch-sim --routed`, and
/// the engine tests.
pub fn synthetic_lpr_router(
    metric: &str,
    rng: &mut Rng,
    d: usize,
    dz: usize,
    e: usize,
    k: usize,
) -> Router {
    let heads = 4usize;
    let dh = dz.div_euclid(heads).max(1);
    let mut proto = normal_vec(rng, e * dz, 1.0);
    for row in proto.chunks_mut(dz.max(1)) {
        let norm: f32 = row.iter().map(|x| x * x).sum::<f32>().sqrt();
        if norm > 0.0 {
            row.iter_mut().for_each(|x| *x /= norm);
        }
    }
    let cfg = RouterConfig {
        kind: RouterKind::Lpr,
        d_model: d,
        n_experts: e,
        top_k: k,
        latent_dim: dz,
        metric: metric.to_string(),
        unit_ball: true,
        gaussian_sigma: 1.0,
        n_score_heads: heads,
    };
    let p = RouterParams {
        norm: vec![1.0; d],
        w_mu: normal_vec(rng, d * dz, 1.0 / (d as f32).sqrt()),
        b_mu: vec![0.0; dz],
        w_lv: normal_vec(rng, d * dz, 0.01),
        b_lv: vec![-4.0; dz],
        proto_mu: proto,
        proto_lv: vec![-2.0; e * dz],
        wq: normal_vec(rng, heads * dz * dh, 0.3),
        wk: normal_vec(rng, heads * dz * dh, 0.3),
        ..Default::default()
    };
    Router::new(cfg, p)
}

fn normal_vec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32 * scale).collect()
}

/// §2.4.1 metric library on flat row-major arrays.
#[allow(clippy::too_many_arguments)]
pub fn metric_scores(
    metric: &str,
    z_mu: &[f32],
    z_lv: &[f32],
    p_mu: &[f32],
    p_lv: &[f32],
    wq: &[f32],
    wk: &[f32],
    n: usize,
    e: usize,
    dz: usize,
    n_heads: usize,
    sigma: f32,
) -> Vec<f32> {
    let mut out = vec![0.0f32; n * e];
    match metric {
        "dot" => {
            for r in 0..n {
                for i in 0..e {
                    let mut s = 0.0;
                    for j in 0..dz {
                        s += z_mu[r * dz + j] * p_mu[i * dz + j];
                    }
                    out[r * e + i] = s;
                }
            }
        }
        "cosine" => {
            let zn: Vec<f32> = (0..n)
                .map(|r| {
                    z_mu[r * dz..(r + 1) * dz]
                        .iter()
                        .map(|x| x * x)
                        .sum::<f32>()
                        .sqrt()
                        + EPS
                })
                .collect();
            let pn: Vec<f32> = (0..e)
                .map(|i| {
                    p_mu[i * dz..(i + 1) * dz]
                        .iter()
                        .map(|x| x * x)
                        .sum::<f32>()
                        .sqrt()
                        + EPS
                })
                .collect();
            for r in 0..n {
                for i in 0..e {
                    let mut s = 0.0;
                    for j in 0..dz {
                        s += z_mu[r * dz + j] * p_mu[i * dz + j];
                    }
                    out[r * e + i] = s / (zn[r] * pn[i]);
                }
            }
        }
        "gaussian" => {
            for r in 0..n {
                for i in 0..e {
                    let mut d2 = 0.0;
                    for j in 0..dz {
                        let d = z_mu[r * dz + j] - p_mu[i * dz + j];
                        d2 += d * d;
                    }
                    out[r * e + i] = (-d2 / (2.0 * sigma * sigma)).exp();
                }
            }
        }
        "mahalanobis" => {
            for r in 0..n {
                for i in 0..e {
                    let mut d2 = 0.0;
                    for j in 0..dz {
                        let d = z_mu[r * dz + j] - p_mu[i * dz + j];
                        d2 += d * d * (-p_lv[i * dz + j]).exp();
                    }
                    out[r * e + i] = -d2;
                }
            }
        }
        "xattn" => {
            let dh = dz.div_euclid(n_heads).max(1);
            for r in 0..n {
                for i in 0..e {
                    let mut s = 0.0;
                    for hh in 0..n_heads {
                        // q = z @ wq[h], kk = p @ wk[h]; accumulate q.k
                        let mut dot = 0.0;
                        for c in 0..dh {
                            let mut q = 0.0;
                            let mut kk = 0.0;
                            for j in 0..dz {
                                q += z_mu[r * dz + j]
                                    * wq[hh * dz * dh + j * dh + c];
                                kk += p_mu[i * dz + j]
                                    * wk[hh * dz * dh + j * dh + c];
                            }
                            dot += q * kk;
                        }
                        s += dot / (dh as f32).sqrt();
                    }
                    out[r * e + i] = s / n_heads as f32;
                }
            }
        }
        "wasserstein" | "kl" | "js" | "hellinger" => {
            for r in 0..n {
                for i in 0..e {
                    let mut acc = 0.0f32;
                    let mut log_bc = 0.0f32;
                    for j in 0..dz {
                        let m1 = z_mu[r * dz + j];
                        let m2 = p_mu[i * dz + j];
                        let v1 = z_lv[r * dz + j].exp();
                        let v2 = p_lv[i * dz + j].exp();
                        let dm2 = (m1 - m2) * (m1 - m2);
                        match metric {
                            "wasserstein" => {
                                let ds = v1.sqrt() - v2.sqrt();
                                acc += dm2 + ds * ds;
                            }
                            "kl" => {
                                acc += 0.5
                                    * ((v2 / v1).ln() + (v1 + dm2) / v2
                                        - 1.0);
                            }
                            "js" => {
                                let v0 = 0.5 * (v1 + v2);
                                let m0 = 0.5 * (m1 + m2);
                                acc += 0.25
                                    * (((v1 + v2) * (v1 + v2)
                                        / (4.0 * v1 * v2))
                                        .ln()
                                        + (v1 + (m1 - m0) * (m1 - m0)) / v0
                                        + (v2 + (m2 - m0) * (m2 - m0)) / v0
                                        - 2.0);
                            }
                            "hellinger" => {
                                let s1 = v1.sqrt();
                                let s2 = v2.sqrt();
                                log_bc += 0.5
                                    * (2.0 * s1 * s2 / (v1 + v2) + EPS).ln()
                                    - 0.25 * dm2 / (v1 + v2);
                            }
                            _ => unreachable!(),
                        }
                    }
                    out[r * e + i] = if metric == "hellinger" {
                        -(1.0 - log_bc.exp())
                    } else {
                        -acc
                    };
                }
            }
        }
        other => panic!("unknown metric '{other}'"),
    }
    out
}

// ---------------------------------------------------------------------
// Construction from artifact metadata / golden files
// ---------------------------------------------------------------------

fn leaf(params: &Json, key: &str) -> Vec<f32> {
    params
        .get(&format!("['{key}']"))
        .map(|j| j.as_f32_flat())
        .unwrap_or_default()
}

impl Router {
    /// Build from a golden JSON file's `config` + `router_params`.
    pub fn from_golden(g: &Json) -> Router {
        let c = g.at("config");
        let kind = match c.at("router").as_str().unwrap() {
            "vanilla" => RouterKind::Vanilla,
            "deepseek" => RouterKind::DeepSeek,
            "lpr" => RouterKind::Lpr,
            other => panic!("unknown router kind {other}"),
        };
        let cfg = RouterConfig {
            kind,
            d_model: c.at("d_model").as_usize().unwrap(),
            n_experts: c.at("n_experts").as_usize().unwrap(),
            top_k: c.at("top_k").as_usize().unwrap(),
            latent_dim: c.at("latent_dim").as_usize().unwrap(),
            metric: c.at("metric").as_str().unwrap().to_string(),
            unit_ball: c.at("unit_ball").as_bool().unwrap(),
            gaussian_sigma: c.at("gaussian_sigma").as_f64().unwrap() as f32,
            n_score_heads: c.at("n_score_heads").as_usize().unwrap(),
        };
        let rp = g.at("router_params");
        let p = RouterParams {
            wg: leaf(rp, "wg"),
            bias: leaf(rp, "bias"),
            norm: leaf(rp, "norm"),
            w_mu: leaf(rp, "w_mu"),
            b_mu: leaf(rp, "b_mu"),
            w_lv: leaf(rp, "w_lv"),
            b_lv: leaf(rp, "b_lv"),
            proto_mu: leaf(rp, "proto_mu"),
            proto_lv: leaf(rp, "proto_lv"),
            wq: leaf(rp, "wq"),
            wk: leaf(rp, "wk"),
        };
        Router::new(cfg, p)
    }
}

#[cfg(test)]
#[allow(deprecated)] // the legacy façade is pinned against the plan path
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32 * scale).collect()
    }

    fn lpr_router(metric: &str, rng: &mut Rng) -> Router {
        let (d, dz, e) = (16, 8, 6);
        let cfg = RouterConfig {
            kind: RouterKind::Lpr,
            d_model: d,
            n_experts: e,
            top_k: 2,
            latent_dim: dz,
            metric: metric.to_string(),
            unit_ball: true,
            gaussian_sigma: 1.0,
            n_score_heads: 4,
        };
        let dh = dz / 4;
        let p = RouterParams {
            norm: vec![1.0; d],
            w_mu: rand_vec(rng, d * dz, 0.3),
            b_mu: vec![0.0; dz],
            w_lv: rand_vec(rng, d * dz, 0.05),
            b_lv: vec![-4.0; dz],
            proto_mu: rand_vec(rng, e * dz, 0.5),
            proto_lv: vec![-2.0; e * dz],
            wq: rand_vec(rng, 4 * dz * dh, 0.4),
            wk: rand_vec(rng, 4 * dz * dh, 0.4),
            ..Default::default()
        };
        Router::new(cfg, p)
    }

    #[test]
    fn top_k_orders_and_breaks_ties_low_index() {
        assert_eq!(top_k_indices(&[1.0, 3.0, 3.0, 2.0], 3), vec![1, 2, 3]);
        assert_eq!(top_k_indices(&[5.0, 1.0], 1), vec![0]);
    }

    #[test]
    fn top_k_nan_loses_deterministically() {
        // NaN must rank after every real score, regardless of position
        let nan = f32::NAN;
        assert_eq!(top_k_indices(&[nan, 1.0, nan, 0.5], 2), vec![1, 3]);
        assert_eq!(top_k_indices(&[1.0, nan, 0.5, nan], 3), vec![0, 2, 1]);
        // all-NaN row: lower index first (still a total order)
        assert_eq!(top_k_indices(&[nan, nan, nan], 2), vec![0, 1]);
        // negative scores still beat NaN
        assert_eq!(top_k_indices(&[nan, -5.0], 1), vec![1]);
        // and the reversed row selects the mirrored indices — the old
        // unwrap_or(Equal) comparator failed this permutation check
        let fwd = top_k_indices(&[2.0, nan, 1.0, nan, 3.0], 3);
        let rev = top_k_indices(&[3.0, nan, 1.0, nan, 2.0], 3);
        assert_eq!(fwd, vec![4, 0, 2]);
        assert_eq!(rev, vec![0, 4, 2]);
    }

    #[test]
    fn all_metrics_route_and_conserve_load() {
        let mut rng = Rng::new(5);
        for metric in METRICS {
            let r = lpr_router(metric, &mut rng);
            let n = 32;
            let h = rand_vec(&mut rng, n * r.cfg.d_model, 1.0);
            let out = r.forward(&h);
            assert_eq!(out.topk_idx.len(), n);
            let total: f32 = out.load.iter().sum();
            assert_eq!(total as usize, n * r.cfg.top_k, "metric {metric}");
            for w in &out.weights {
                let s: f32 = w.iter().sum();
                assert!((s - 1.0).abs() < 1e-4, "{metric}: {s}");
            }
        }
    }

    #[test]
    fn weights_sum_to_one_property() {
        forall(
            30,
            77,
            |rng| {
                let r = lpr_router("cosine", &mut rng.clone());
                let h = rand_vec(rng, 8 * 16, 1.0);
                (r, h)
            },
            |(r, h)| {
                let out = r.forward(h);
                for w in &out.weights {
                    let s: f32 = w.iter().sum();
                    if (s - 1.0).abs() > 1e-4 {
                        return Err(format!("weights sum {s}"));
                    }
                    if w.windows(2).any(|p| p[0] < p[1] - 1e-6) {
                        return Err("weights not descending".into());
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn deepseek_bias_forces_selection_not_weights() {
        let (d, e) = (8, 4);
        let mut rng = Rng::new(3);
        let cfg = RouterConfig {
            kind: RouterKind::DeepSeek,
            d_model: d,
            n_experts: e,
            top_k: 2,
            latent_dim: 0,
            metric: "dot".into(),
            unit_ball: false,
            gaussian_sigma: 1.0,
            n_score_heads: 1,
        };
        let mut p = RouterParams {
            wg: rand_vec(&mut rng, d * e, 0.5),
            bias: vec![0.0; e],
            ..Default::default()
        };
        p.bias[3] = 100.0;
        let r = Router::new(cfg, p);
        let h = rand_vec(&mut rng, 16 * d, 1.0);
        let out = r.forward(&h);
        for row in &out.topk_idx {
            assert!(row.contains(&3));
        }
        // weights normalized from raw affinities: within (0, 1]
        for w in out.weights.iter().flatten() {
            assert!(*w > 0.0 && *w <= 1.0 + 1e-5);
        }
    }

    #[test]
    fn vanilla_matches_manual_computation() {
        // d=2, E=3; h=[1,0] -> scores = first row of wg
        let cfg = RouterConfig {
            kind: RouterKind::Vanilla,
            d_model: 2,
            n_experts: 3,
            top_k: 2,
            latent_dim: 0,
            metric: "dot".into(),
            unit_ball: false,
            gaussian_sigma: 1.0,
            n_score_heads: 1,
        };
        let p = RouterParams {
            wg: vec![0.5, 2.0, 1.0, /* row2 */ 0.0, 0.0, 0.0],
            ..Default::default()
        };
        let r = Router::new(cfg, p);
        let out = r.forward(&[1.0, 0.0]);
        assert_eq!(out.topk_idx[0], vec![1, 2]);
        let w = &out.weights[0];
        let e0 = (2.0f32 - 2.0).exp();
        let e1 = (1.0f32 - 2.0).exp();
        assert!((w[0] - e0 / (e0 + e1)).abs() < 1e-6);
    }

    #[test]
    fn unit_ball_projection_only_shrinks() {
        let mut rng = Rng::new(11);
        let r0 = lpr_router("gaussian", &mut rng);
        let mut p = r0.p.clone();
        for v in p.proto_mu.iter_mut() {
            *v *= 50.0; // blow up prototypes
        }
        // projection now happens once, at construction
        let r = Router::new(r0.cfg.clone(), p);
        let dz = r.cfg.latent_dim;
        for row in r.p.proto_mu.chunks(dz) {
            let norm: f32 = row.iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!(norm <= 1.0 + 1e-5, "row not projected: {norm}");
        }
        let h = rand_vec(&mut rng, 4 * 16, 1.0);
        let out = r.forward(&h);
        // gaussian scores must stay well away from underflow because
        // prototypes were projected back into the unit ball
        let max_w = out.weights.iter().flatten().cloned().fold(0.0, f32::max);
        assert!(max_w > 0.4);
    }
}
