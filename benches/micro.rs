//! Microbenchmarks for the L3 hot paths (no artifacts needed):
//! serving router across metrics, dispatch simulator, metric kernels,
//! data pipeline, JSON parsing.
//!
//! Run: `cargo bench --bench micro` (results appended to
//! `results/bench.csv`).

use lpr::data::{Batcher, ZipfMarkovCorpus};
use lpr::dispatch::{synthetic_assignments, DispatchSim, SimConfig};
use lpr::metrics::{gini, min_max_ratio};
use lpr::router::linalg::matmul;
use lpr::router::{Router, RouterConfig, RouterKind, RouterParams};
use lpr::util::bench::Bench;
use lpr::util::json::Json;
use lpr::util::rng::Rng;

fn normal_vec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32 * scale).collect()
}

fn lpr_router(metric: &str, rng: &mut Rng, d: usize, dz: usize, e: usize,
              k: usize) -> Router {
    let heads = 4;
    let dh = (dz / heads).max(1);
    Router::new(
        RouterConfig {
            kind: RouterKind::Lpr,
            d_model: d,
            n_experts: e,
            top_k: k,
            latent_dim: dz,
            metric: metric.into(),
            unit_ball: true,
            gaussian_sigma: 1.0,
            n_score_heads: heads,
        },
        RouterParams {
            norm: vec![1.0; d],
            w_mu: normal_vec(rng, d * dz, 0.1),
            b_mu: vec![0.0; dz],
            w_lv: normal_vec(rng, d * dz, 0.01),
            b_lv: vec![-4.0; dz],
            proto_mu: normal_vec(rng, e * dz, 0.5),
            proto_lv: vec![-2.0; e * dz],
            wq: normal_vec(rng, heads * dz * dh, 0.3),
            wk: normal_vec(rng, heads * dz * dh, 0.3),
            ..Default::default()
        },
    )
}

fn main() {
    let mut b = Bench::new("micro");
    let mut rng = Rng::new(1);

    // ---- serving router: tokens/s per metric (paper-scale E=128) ----
    let (d, dz, e, k, n) = (256usize, 16usize, 128usize, 8usize, 1024usize);
    let h = normal_vec(&mut rng, n * d, 1.0);
    for metric in ["dot", "cosine", "gaussian", "wasserstein", "xattn"] {
        let r = lpr_router(metric, &mut rng, d, dz, e, k);
        b.run_items(&format!("router_fwd/{metric}/{n}tok"), n as f64,
                    &mut || {
            std::hint::black_box(r.forward(&h));
        });
    }
    // vanilla for comparison (d x E matmul dominates)
    let van = Router::new(
        RouterConfig {
            kind: RouterKind::Vanilla,
            d_model: d,
            n_experts: e,
            top_k: k,
            latent_dim: 0,
            metric: "dot".into(),
            unit_ball: false,
            gaussian_sigma: 1.0,
            n_score_heads: 1,
        },
        RouterParams { wg: normal_vec(&mut rng, d * e, 0.1),
                       ..Default::default() },
    );
    b.run_items(&format!("router_fwd/vanilla/{n}tok"), n as f64, &mut || {
        std::hint::black_box(van.forward(&h));
    });

    // ---- dispatch simulator ----
    let assignments =
        synthetic_assignments(&mut rng, 2048, 8, 64, 0.7);
    b.run_items("dispatch_sim/step/2048tok", 2048.0, &mut || {
        let mut sim = DispatchSim::new(SimConfig::default());
        sim.step(std::hint::black_box(&assignments));
        std::hint::black_box(sim.report());
    });

    // ---- metrics ----
    let load = normal_vec(&mut rng, 512, 1.0)
        .iter()
        .map(|x| x.abs())
        .collect::<Vec<_>>();
    b.run("gini/512experts", || {
        std::hint::black_box(gini(std::hint::black_box(&load)));
    });
    b.run("min_max/512experts", || {
        std::hint::black_box(min_max_ratio(std::hint::black_box(&load)));
    });

    // ---- data pipeline ----
    let mut corpus = ZipfMarkovCorpus::standard(512, 3);
    let batcher = Batcher::new(8, 128);
    b.run_items("corpus/batch_8x128", 1024.0, &mut || {
        std::hint::black_box(batcher.next_synthetic(&mut corpus));
    });

    // ---- json (meta parsing path) ----
    let meta = std::fs::read_to_string(
        lpr::default_art_dir().join("quickstart.meta.json"),
    )
    .unwrap_or_else(|_| "{\"a\": [1,2,3]}".into());
    b.run("json/parse_meta", || {
        std::hint::black_box(Json::parse(std::hint::black_box(&meta)).unwrap());
    });

    // ---- dense matmul bound (router roofline reference) ----
    let a = normal_vec(&mut rng, n * d, 1.0);
    let w = normal_vec(&mut rng, d * e, 1.0);
    b.run_items("linalg/matmul_1024x256x128", n as f64, &mut || {
        std::hint::black_box(matmul(
            std::hint::black_box(&a),
            std::hint::black_box(&w),
            n,
            d,
            e,
        ));
    });

    std::fs::create_dir_all("results").ok();
    b.write_csv(std::path::Path::new("results/bench.csv")).ok();
}
