//! Microbenchmarks for the L3 hot paths (no artifacts needed):
//! serving router (legacy vs compiled plan vs sharded engine) across
//! the full metric library, dispatch simulator, metric kernels, data
//! pipeline, JSON parsing.
//!
//! Run: `cargo bench --bench micro` (results appended to
//! `results/bench.csv`; the routing sweep is also written as
//! machine-readable JSON to `BENCH_router.json`, the dispatch-plan /
//! full expert-forward sweep — scoped *and* persistent-pool — to
//! `BENCH_dispatch.json`, the serving-runtime arrival sweep to
//! `BENCH_serve.json`, the stacked-model forward sweep — scoped vs
//! pool backends, layers {1, 4} — to `BENCH_model.json`, the
//! facade-vs-direct overhead rows (boxed `dyn MoeEngine` vs the
//! backend called directly) to `BENCH_engine.json`, and the
//! grouped-GEMM kernel × weight-dtype sweep over the FFN hot loop to
//! `BENCH_gemm.json`, and the expert-placement sweep — pool forward
//! wall-clock plus modelled step latency/stall per planner — to
//! `BENCH_placement.json`, and the admission front-end rows —
//! compiled-matcher classify cost plus a 2x-overload lane run — to
//! `BENCH_admission.json`, and the autoregressive decode sweep —
//! prefill vs KV-cached single-token steps, layers {1, 4} x batch
//! {1, 8, 32} — to `BENCH_decode.json`, so the perf trajectory is
//! trackable across PRs). All serving-path engines are
//! built through `Engine::builder()`; the `engine_direct/*` rows are
//! the deliberate exception — they are the baseline the facade rows
//! compare against. Set `LPR_BENCH_FAST=1` for a short smoke run (CI).

use lpr::data::{Batcher, MixtureStream, ZipfMarkovCorpus};
use lpr::dispatch::{
    capacity_for, run_routed_steps, synthetic_assignments, DispatchPlan,
    DispatchSim, OverflowPolicy, PlacementConfig, PlacementPolicy,
    SimConfig,
};
use lpr::engine::{Backend, Engine, MoeEngine};
use lpr::experts::ExpertBank;
use lpr::metrics::{gini, min_max_ratio};
use lpr::model::cache::{KvCache, SeqSpan};
use lpr::model::{
    synthetic_decoder_model, synthetic_stacked_model, ModelEngine,
    ModelForward,
};
use lpr::router::linalg::matmul;
use lpr::router::{
    synthetic_lpr_router, RouteBuffers, Router, RouterBatch,
    RouterConfig, RouterKind, RouterParams, METRICS,
};
use lpr::serve::{
    measure_engine_rate, run_admitted_open_loop, run_open_loop,
    AdmissionConfig, AdmittedRuntime, PoolEngine, RequestMeta,
    ServeConfig, ServeRuntime,
};
use lpr::util::bench::{write_json_rows, Bench};
use lpr::util::json::Json;
use lpr::util::rng::Rng;

fn normal_vec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32 * scale).collect()
}

/// One row of BENCH_router.json.
struct RouterRow {
    name: String,
    n: usize,
    d: usize,
    e: usize,
    k: usize,
    threads: usize,
    ns_per_token: f64,
}

/// `lpr::util::bench::write_json_rows` with a warning instead of a
/// hard failure (benches should finish even on a read-only results
/// directory).
fn write_rows_or_warn(path: &str, rows: &[String]) {
    if let Err(e) = write_json_rows(path, rows) {
        eprintln!("warn: could not write {path}: {e}");
    }
}

fn write_router_json(rows: &[RouterRow]) {
    let objs: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{{\"name\": \"{}\", \"n\": {}, \"d\": {}, \"E\": {}, \
                 \"k\": {}, \"threads\": {}, \"ns_per_token\": {:.2}}}",
                r.name, r.n, r.d, r.e, r.k, r.threads, r.ns_per_token
            )
        })
        .collect();
    write_rows_or_warn("BENCH_router.json", &objs);
}

/// One row of BENCH_dispatch.json.
struct DispatchRow {
    name: String,
    n: usize,
    d: usize,
    d_ff: usize,
    e: usize,
    k: usize,
    threads: usize,
    ns_per_token: f64,
}

fn write_dispatch_json(rows: &[DispatchRow]) {
    let objs: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{{\"name\": \"{}\", \"n\": {}, \"d\": {}, \
                 \"d_ff\": {}, \"E\": {}, \"k\": {}, \"threads\": {}, \
                 \"ns_per_token\": {:.2}}}",
                r.name, r.n, r.d, r.d_ff, r.e, r.k, r.threads,
                r.ns_per_token
            )
        })
        .collect();
    write_rows_or_warn("BENCH_dispatch.json", &objs);
}

fn main() {
    let mut b = Bench::new("micro");
    if std::env::var("LPR_BENCH_FAST").is_ok() {
        b.target_s = 0.05; // CI smoke mode
    }
    let mut rng = Rng::new(1);
    let mut router_rows: Vec<RouterRow> = Vec::new();

    // ---- serving router: tokens/s per metric (acceptance config:
    // E=64, d=256, top-8) — legacy per-call path vs compiled plan.
    // NOTE: forward_reference already includes the construction-time
    // projection hoist, so the legacy rows slightly understate the
    // true pre-plan cost (see ROADMAP.md perf-trajectory notes). ----
    let (d, dz, e, k, n) = (256usize, 16usize, 64usize, 8usize, 1024usize);
    let h = normal_vec(&mut rng, n * d, 1.0);
    for metric in METRICS {
        let r = synthetic_lpr_router(metric, &mut rng, d, dz, e, k);
        let res = b.run_items(
            &format!("router_legacy/{metric}/{n}tok"),
            n as f64,
            &mut || {
                std::hint::black_box(r.forward_reference(&h));
            },
        );
        router_rows.push(RouterRow {
            name: format!("legacy/{metric}"),
            n,
            d,
            e,
            k,
            threads: 1,
            ns_per_token: res.per_item_ns(),
        });
        let plan = r.plan().clone();
        let mut buf = RouteBuffers::new();
        let mut out = RouterBatch::new();
        let res = b.run_items(
            &format!("router_plan/{metric}/{n}tok"),
            n as f64,
            &mut || {
                plan.forward_into(
                    std::hint::black_box(&h),
                    &mut buf,
                    &mut out,
                );
                std::hint::black_box(&out);
            },
        );
        router_rows.push(RouterRow {
            name: format!("plan/{metric}"),
            n,
            d,
            e,
            k,
            threads: 1,
            ns_per_token: res.per_item_ns(),
        });
    }

    // ---- sharded routing via the engine facade: thread scaling on
    // the LPR hot path (routing-only, so the facade carries a 1-wide
    // placeholder bank — the FFN stage never runs) ----
    let cores = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1);
    for metric in ["cosine", "xattn"] {
        let r = synthetic_lpr_router(metric, &mut rng, d, dz, e, k);
        for threads in [1usize, 2, 4, 8] {
            if threads > cores {
                continue;
            }
            let mut engine = Engine::builder()
                .layer(
                    r.plan().clone(),
                    ExpertBank::new(&Rng::new(0), e, d, 1),
                )
                .backend(Backend::Scoped { threads })
                .build()
                .expect("valid engine config");
            let mut out = RouterBatch::new();
            let res = b.run_items(
                &format!("router_engine/{metric}/t{threads}/{n}tok"),
                n as f64,
                &mut || {
                    engine.route_into(std::hint::black_box(&h), &mut out);
                    std::hint::black_box(&out);
                },
            );
            router_rows.push(RouterRow {
                name: format!("engine/{metric}"),
                n,
                d,
                e,
                k,
                threads,
                ns_per_token: res.per_item_ns(),
            });
        }
    }

    // vanilla for comparison (d x E matmul dominates)
    let van = Router::new(
        RouterConfig {
            kind: RouterKind::Vanilla,
            d_model: d,
            n_experts: e,
            top_k: k,
            latent_dim: 0,
            metric: "dot".into(),
            unit_ball: false,
            gaussian_sigma: 1.0,
            n_score_heads: 1,
        },
        RouterParams { wg: normal_vec(&mut rng, d * e, 0.1),
                       ..Default::default() },
    );
    {
        let plan = van.plan().clone();
        let mut buf = RouteBuffers::new();
        let mut out = RouterBatch::new();
        let res = b.run_items(
            &format!("router_plan/vanilla/{n}tok"),
            n as f64,
            &mut || {
                plan.forward_into(
                    std::hint::black_box(&h),
                    &mut buf,
                    &mut out,
                );
                std::hint::black_box(&out);
            },
        );
        router_rows.push(RouterRow {
            name: "plan/vanilla".into(),
            n,
            d,
            e,
            k,
            threads: 1,
            ns_per_token: res.per_item_ns(),
        });
    }

    write_router_json(&router_rows);

    // ---- dispatch plans + full expert-parallel forward: per-policy
    // plan-build and route->plan->FFN->combine ns/token, emitted as
    // BENCH_dispatch.json for the cross-PR perf trajectory ----
    {
        let (dd, dz, de, dk, dn, dff) =
            (64usize, 16usize, 64usize, 8usize, 1024usize, 256usize);
        let mut dispatch_rows: Vec<DispatchRow> = Vec::new();
        let router = synthetic_lpr_router("cosine", &mut rng, dd, dz, de, dk);
        let bank = ExpertBank::new(&lpr::util::rng::Rng::new(42), de, dd, dff);
        let mix = MixtureStream::skewed(&mut rng, dd, 1.6);
        let mut hd = Vec::new();
        mix.fill(&mut rng, dn, &mut hd);
        let batch = router.plan().forward(&hd);
        let cap = capacity_for(batch.topk_idx.len(), de, 1.0);
        for policy in OverflowPolicy::ALL {
            let mut plan = DispatchPlan::new();
            let res = b.run_items(
                &format!("dispatch_plan/{}/{dn}tok", policy.name()),
                dn as f64,
                &mut || {
                    plan.compile_batch(
                        std::hint::black_box(&batch),
                        cap,
                        policy,
                    );
                    std::hint::black_box(&plan);
                },
            );
            dispatch_rows.push(DispatchRow {
                name: format!("plan_build/{}", policy.name()),
                n: dn,
                d: dd,
                d_ff: dff,
                e: de,
                k: dk,
                threads: 1,
                ns_per_token: res.per_item_ns(),
            });
            for threads in [1usize, 4] {
                if threads > cores {
                    continue;
                }
                let mut eng = Engine::builder()
                    .layer(router.plan().clone(), bank.clone())
                    .backend(Backend::Scoped { threads })
                    .policy(policy)
                    .capacity_factor(1.0)
                    .build()
                    .expect("valid engine config");
                let res = b.run_items(
                    &format!(
                        "dispatch_full/{}/t{threads}/{dn}tok",
                        policy.name()
                    ),
                    dn as f64,
                    &mut || {
                        let out =
                            eng.forward(std::hint::black_box(&hd), dn);
                        std::hint::black_box(out.hidden.len());
                    },
                );
                dispatch_rows.push(DispatchRow {
                    name: format!("full_forward/{}", policy.name()),
                    n: dn,
                    d: dd,
                    d_ff: dff,
                    e: de,
                    k: dk,
                    threads,
                    ns_per_token: res.per_item_ns(),
                });
                // persistent pool vs scoped threads on the same batch:
                // the spawn-per-batch fixed cost the pool removes —
                // under the facade the swap is one builder word
                let mut pool = Engine::builder()
                    .layer(router.plan().clone(), bank.clone())
                    .backend(Backend::Pool { workers: threads })
                    .policy(policy)
                    .capacity_factor(1.0)
                    .build()
                    .expect("valid engine config");
                let res = b.run_items(
                    &format!(
                        "pool_full/{}/t{threads}/{dn}tok",
                        policy.name()
                    ),
                    dn as f64,
                    &mut || {
                        let out =
                            pool.forward(std::hint::black_box(&hd), dn);
                        std::hint::black_box(out.hidden.len());
                    },
                );
                dispatch_rows.push(DispatchRow {
                    name: format!("pool_forward/{}", policy.name()),
                    n: dn,
                    d: dd,
                    d_ff: dff,
                    e: de,
                    k: dk,
                    threads,
                    ns_per_token: res.per_item_ns(),
                });
            }
        }
        write_dispatch_json(&dispatch_rows);
    }

    // ---- serving runtime: open-loop arrival sweep through the
    // persistent pool + micro-batch queue, emitted as BENCH_serve.json
    // (policy × workers × arrival-rate -> p50/p99/throughput) ----
    {
        let fast = std::env::var("LPR_BENCH_FAST").is_ok();
        let (sd, sdz, se, sk, sff) = (32usize, 16usize, 64usize, 4usize, 64usize);
        let (req_tokens, max_batch) = (32usize, 256usize);
        let n_requests = if fast { 64 } else { 256 };
        let workers_sweep: Vec<usize> =
            [1usize, 4].iter().cloned().filter(|&w| w <= cores).collect();
        let mut serve_rows: Vec<String> = Vec::new();
        for &workers in &workers_sweep {
            let mut rng = Rng::new(23);
            let router =
                synthetic_lpr_router("cosine", &mut rng, sd, sdz, se, sk);
            let bank = ExpertBank::new(&Rng::new(42), se, sd, sff);
            let mix = MixtureStream::skewed(&mut rng, sd, 1.6);
            let mut cal = Engine::builder()
                .layer(router.plan().clone(), bank.clone())
                .backend(Backend::Pool { workers })
                .policy(OverflowPolicy::Drop)
                .capacity_factor(1.25)
                .build()
                .expect("valid engine config");
            let cap_tok_s = measure_engine_rate(
                &mut cal, &mix, &mut rng, max_batch, 3,
            );
            drop(cal);
            for policy in OverflowPolicy::ALL {
                for load in [0.5f64, 2.0] {
                    let mut rng = Rng::new(23);
                    let router = synthetic_lpr_router(
                        "cosine", &mut rng, sd, sdz, se, sk,
                    );
                    let bank = ExpertBank::new(&Rng::new(42), se, sd, sff);
                    let mix = MixtureStream::skewed(&mut rng, sd, 1.6);
                    let engine = Engine::builder()
                        .layer(router.plan().clone(), bank)
                        .backend(Backend::Pool { workers })
                        .policy(policy)
                        .capacity_factor(1.25)
                        .build()
                        .expect("valid engine config");
                    let cfg = ServeConfig {
                        max_batch,
                        max_wait: 2_000,
                        queue_tokens: 8 * max_batch,
                        ..ServeConfig::default()
                    };
                    let mut srv = ServeRuntime::with_engine(
                        engine.into_inner(),
                        cfg,
                    );
                    let t0 = std::time::Instant::now();
                    run_open_loop(
                        &mut srv,
                        &mix,
                        &mut rng,
                        n_requests,
                        req_tokens,
                        load * cap_tok_s,
                    );
                    let wall = t0.elapsed().as_secs_f64();
                    let r = srv.report();
                    println!(
                        "micro/serve/{}/w{workers}/load{load}    \
                         p50 {:>7.0} us  p99 {:>7.0} us  {:>10.0} tok/s \
                         ({} batches, {:.2}s wall)",
                        policy.name(),
                        r.latency_p50_us,
                        r.latency_p99_us,
                        r.throughput_tok_per_s,
                        r.batches,
                        wall
                    );
                    serve_rows.push(r.bench_json_row(
                        policy,
                        workers,
                        load * cap_tok_s,
                        load,
                        req_tokens,
                    ));
                }
            }
        }
        write_rows_or_warn("BENCH_serve.json", &serve_rows);
    }

    // ---- stacked model forward: scoped ModelEngine vs persistent
    // pool, layers {1, 4} x workers {1, 4}, emitted as
    // BENCH_model.json (route -> plan -> FFN -> combine -> residual,
    // per layer) ----
    {
        let (md, mdz, me, mk, mff, mn) =
            (32usize, 16usize, 32usize, 4usize, 64usize, 512usize);
        let mut model_rows: Vec<String> = Vec::new();
        let mut push_row = |name: &str,
                            layers: usize,
                            workers: usize,
                            ns_per_token: f64| {
            model_rows.push(format!(
                "{{\"name\": \"{name}\", \"layers\": {layers}, \
                 \"n\": {mn}, \"d\": {md}, \"d_ff\": {mff}, \
                 \"E\": {me}, \"k\": {mk}, \"workers\": {workers}, \
                 \"ns_per_token\": {ns_per_token:.2}}}"
            ));
        };
        for n_layers in [1usize, 4] {
            let model = synthetic_stacked_model(
                "cosine",
                &Rng::new(2025),
                n_layers,
                md,
                mdz,
                me,
                mk,
                mff,
            );
            let mut rng = Rng::new(7);
            let mix = MixtureStream::skewed(&mut rng, md, 1.6);
            let mut hm = Vec::new();
            mix.fill(&mut rng, mn, &mut hm);
            for workers in [1usize, 4] {
                if workers > cores {
                    continue;
                }
                let mut eng = Engine::builder()
                    .model(model.clone())
                    .backend(Backend::Scoped { threads: workers })
                    .policy(OverflowPolicy::Drop)
                    .capacity_factor(1.25)
                    .build()
                    .expect("valid engine config");
                let res = b.run_items(
                    &format!(
                        "model_forward/scoped/L{n_layers}/t{workers}/\
                         {mn}tok"
                    ),
                    mn as f64,
                    &mut || {
                        let out =
                            eng.forward(std::hint::black_box(&hm), mn);
                        std::hint::black_box(out.hidden.len());
                    },
                );
                push_row(
                    &format!("model_forward/scoped/L{n_layers}"),
                    n_layers,
                    workers,
                    res.per_item_ns(),
                );
                let mut pool = Engine::builder()
                    .model(model.clone())
                    .backend(Backend::Pool { workers })
                    .policy(OverflowPolicy::Drop)
                    .capacity_factor(1.25)
                    .build()
                    .expect("valid engine config");
                let res = b.run_items(
                    &format!(
                        "model_forward/pool/L{n_layers}/t{workers}/\
                         {mn}tok"
                    ),
                    mn as f64,
                    &mut || {
                        let out =
                            pool.forward(std::hint::black_box(&hm), mn);
                        std::hint::black_box(out.hidden.len());
                    },
                );
                push_row(
                    &format!("model_forward/pool/L{n_layers}"),
                    n_layers,
                    workers,
                    res.per_item_ns(),
                );
            }
        }
        write_rows_or_warn("BENCH_model.json", &model_rows);
    }

    // ---- autoregressive decode: the same T tokens per sequence
    // through the cached sequence path, either as one prefill call or
    // as T single-token decode steps (the generation loop's shape).
    // Attention decoders, layers {1, 4} x batch {1, 8, 32}, no-drop
    // cf = E so both paths do identical routing work. Emitted as
    // BENCH_decode.json. ----
    {
        let (dd, ddz, de, dk, dff, dh, dv, dt) =
            (32usize, 16usize, 16usize, 2usize, 64usize, 4usize,
             64usize, 32usize);
        let mut decode_rows: Vec<String> = Vec::new();
        let mut push_row = |name: &str,
                            layers: usize,
                            batch: usize,
                            ns_per_token: f64| {
            decode_rows.push(format!(
                "{{\"name\": \"{name}\", \"layers\": {layers}, \
                 \"batch\": {batch}, \"seq\": {dt}, \"d\": {dd}, \
                 \"d_ff\": {dff}, \"E\": {de}, \"k\": {dk}, \
                 \"heads\": {dh}, \"ns_per_token\": {ns_per_token:.2}}}"
            ));
        };
        for n_layers in [1usize, 4] {
            let (model, _head) = synthetic_decoder_model(
                "cosine",
                &Rng::new(2025),
                n_layers,
                dd,
                ddz,
                de,
                dk,
                dff,
                dh,
                dv,
            )
            .into_parts();
            for batch in [1usize, 8, 32] {
                let mut eng = Engine::builder()
                    .model(model.clone())
                    .backend(Backend::Scoped { threads: 1 })
                    .capacity_factor(de as f64)
                    .build()
                    .expect("valid engine config");
                let mut rng = Rng::new(7);
                // per-sequence activations: batch sequences x dt rows
                let h_full = normal_vec(&mut rng, batch * dt * dd, 0.5);
                // the same rows re-laid-out one decode step at a time:
                // step t holds every sequence's t-th token row
                let h_steps: Vec<Vec<f32>> = (0..dt)
                    .map(|t| {
                        let mut rows = Vec::with_capacity(batch * dd);
                        for s in 0..batch {
                            let at = (s * dt + t) * dd;
                            rows.extend_from_slice(
                                &h_full[at..at + dd],
                            );
                        }
                        rows
                    })
                    .collect();
                let mut cache =
                    KvCache::new(batch, n_layers, dd, dt);
                let slots: Vec<usize> = (0..batch)
                    .map(|_| cache.alloc().expect("slot"))
                    .collect();
                let full_spans: Vec<SeqSpan> = slots
                    .iter()
                    .map(|&slot| SeqSpan { slot, n_tokens: dt })
                    .collect();
                let step_spans: Vec<SeqSpan> = slots
                    .iter()
                    .map(|&slot| SeqSpan { slot, n_tokens: 1 })
                    .collect();

                let res = b.run_items(
                    &format!(
                        "decode/prefill/L{n_layers}/b{batch}/{dt}tok"
                    ),
                    (batch * dt) as f64,
                    &mut || {
                        for &slot in &slots {
                            cache.reset(slot);
                        }
                        let out = eng.forward_seqs(
                            std::hint::black_box(&h_full),
                            &full_spans,
                            &mut cache,
                        );
                        std::hint::black_box(out.hidden.len());
                    },
                );
                push_row(
                    &format!("decode/prefill/L{n_layers}"),
                    n_layers,
                    batch,
                    res.per_item_ns(),
                );

                let res = b.run_items(
                    &format!(
                        "decode/cached/L{n_layers}/b{batch}/{dt}tok"
                    ),
                    (batch * dt) as f64,
                    &mut || {
                        for &slot in &slots {
                            cache.reset(slot);
                        }
                        for step_h in &h_steps {
                            let out = eng.forward_seqs(
                                std::hint::black_box(step_h),
                                &step_spans,
                                &mut cache,
                            );
                            std::hint::black_box(out.hidden.len());
                        }
                    },
                );
                push_row(
                    &format!("decode/cached/L{n_layers}"),
                    n_layers,
                    batch,
                    res.per_item_ns(),
                );
            }
        }
        write_rows_or_warn("BENCH_decode.json", &decode_rows);
    }

    // ---- engine facade overhead: the same forward through a boxed
    // `dyn MoeEngine` vs the backend called directly. These are the
    // only direct backend constructions left outside rust/src/engine/
    // — they ARE the baseline this sweep exists to compare against.
    // Claim under test: ≈0 ns/token for the trait-object indirection
    // at batch sizes >= 256. Emitted as BENCH_engine.json. ----
    {
        let (fd, fdz, fe, fk, fff) =
            (32usize, 16usize, 32usize, 4usize, 64usize);
        let mut engine_rows: Vec<String> = Vec::new();
        let model = synthetic_stacked_model(
            "cosine",
            &Rng::new(2025),
            1,
            fd,
            fdz,
            fe,
            fk,
            fff,
        );
        let mut rng = Rng::new(11);
        let mix = MixtureStream::skewed(&mut rng, fd, 1.6);
        let mut push_row = |name: &str, n: usize, ns: f64| {
            engine_rows.push(format!(
                "{{\"name\": \"{name}\", \"n\": {n}, \"d\": {fd}, \
                 \"d_ff\": {fff}, \"E\": {fe}, \"k\": {fk}, \
                 \"threads\": 1, \"ns_per_token\": {ns:.2}}}"
            ));
        };
        let boxed = |backend: Backend| -> Box<dyn MoeEngine> {
            Engine::builder()
                .model(model.clone())
                .backend(backend)
                .policy(OverflowPolicy::Drop)
                .capacity_factor(1.25)
                .build()
                .expect("valid engine config")
                .into_inner()
        };
        for n in [256usize, 1024] {
            let mut hf = Vec::new();
            mix.fill(&mut rng, n, &mut hf);
            // scoped backend: direct ModelEngine vs boxed facade
            let mut direct = ModelEngine::new(model.clone(), 1);
            let mut out = ModelForward::new();
            let res = b.run_items(
                &format!("engine_direct/scoped/{n}tok"),
                n as f64,
                &mut || {
                    direct.forward(
                        std::hint::black_box(&hf),
                        1.25,
                        OverflowPolicy::Drop,
                        &mut out,
                    );
                    std::hint::black_box(&out);
                },
            );
            push_row("direct/scoped", n, res.per_item_ns());
            let mut facade = boxed(Backend::Scoped { threads: 1 });
            let res = b.run_items(
                &format!("engine_facade/scoped/{n}tok"),
                n as f64,
                &mut || {
                    let o = facade.forward(std::hint::black_box(&hf), n);
                    std::hint::black_box(o.hidden.len());
                },
            );
            push_row("facade/scoped", n, res.per_item_ns());
            // pool backend: direct PoolEngine vs boxed facade
            let mut dpool = PoolEngine::from_model(model.clone(), 1);
            let mut pout = ModelForward::new();
            let res = b.run_items(
                &format!("engine_direct/pool/{n}tok"),
                n as f64,
                &mut || {
                    dpool.forward_model(
                        std::hint::black_box(&hf),
                        1.25,
                        OverflowPolicy::Drop,
                        &mut pout,
                    );
                    std::hint::black_box(&pout);
                },
            );
            push_row("direct/pool", n, res.per_item_ns());
            let mut fpool = boxed(Backend::Pool { workers: 1 });
            let res = b.run_items(
                &format!("engine_facade/pool/{n}tok"),
                n as f64,
                &mut || {
                    let o = fpool.forward(std::hint::black_box(&hf), n);
                    std::hint::black_box(o.hidden.len());
                },
            );
            push_row("facade/pool", n, res.per_item_ns());
        }
        write_rows_or_warn("BENCH_engine.json", &engine_rows);
    }

    // ---- grouped-GEMM micro-kernels: the FFN hot loop across every
    // kernel × weight dtype × plain/gated bank at the acceptance
    // shapes (E=32, d ∈ {32, 256}, d_ff = 4·d), plus an m_per_expert
    // sweep and a small MC×KC×NC tile grid, emitted as
    // BENCH_gemm.json. Rows carry "simd"/"neon" flags: without the
    // matching feature + runtime support those rows measure the
    // scalar register-tile fallback. ----
    {
        use lpr::kernels::{
            neon_available, simd_available, GemmTiles, Kernel,
            WeightDtype,
        };
        let fast = std::env::var("LPR_BENCH_FAST").is_ok();
        let ge = 32usize;
        let gm = if fast { 8usize } else { 32 }; // rows per expert
        let mut gemm_rows: Vec<String> = Vec::new();
        let mut push_gemm_row = |name: &str,
                                 gd: usize,
                                 gff: usize,
                                 gm: usize,
                                 tiles: Option<GemmTiles>,
                                 ns: f64| {
            let tiles_field = match tiles {
                Some(t) => format!("\"{t}\""),
                None => "\"default\"".to_string(),
            };
            gemm_rows.push(format!(
                "{{\"name\": \"{name}\", \"E\": {ge}, \"d\": {gd}, \
                 \"d_ff\": {gff}, \"m_per_expert\": {gm}, \
                 \"tiles\": {tiles_field}, \"simd\": {}, \
                 \"neon\": {}, \"ns_per_token\": {:.2}}}",
                simd_available(),
                neon_available(),
                ns
            ));
        };
        let gated_bank = |seed: u64, e: usize, d: usize, ff: usize| {
            let mut grng = Rng::new(seed);
            let w1 = normal_vec(&mut grng, e * d * ff, 0.05);
            let w3 = normal_vec(&mut grng, e * d * ff, 0.05);
            let w2 = normal_vec(&mut grng, e * ff * d, 0.05);
            ExpertBank::from_weights_gated(e, d, ff, w1, w3, w2)
        };
        // kernel × dtype × plain/gated at the acceptance shapes
        for gd in [32usize, 256] {
            let gff = 4 * gd;
            let bank_f32 = ExpertBank::new(&Rng::new(77), ge, gd, gff);
            let gated_f32 = gated_bank(78, ge, gd, gff);
            let x = normal_vec(&mut rng, gm * gd, 1.0);
            let mut hid = Vec::new();
            let mut out = vec![0.0f32; gm * gd];
            for dtype in WeightDtype::ALL {
                for (tag, src) in
                    [("plain", &bank_f32), ("gated", &gated_f32)]
                {
                    let bank = src.quantized(dtype).unwrap();
                    for kernel in Kernel::ALL {
                        let res = b.run_items(
                            &format!(
                                "gemm/{}/{}/{tag}/d{gd}",
                                kernel.name(),
                                dtype.name()
                            ),
                            (gm * ge) as f64,
                            &mut || {
                                for ei in 0..ge {
                                    bank.forward_rows_with(
                                        kernel,
                                        ei,
                                        std::hint::black_box(&x),
                                        gm,
                                        &mut hid,
                                        &mut out,
                                    );
                                }
                                std::hint::black_box(&out);
                            },
                        );
                        push_gemm_row(
                            &format!(
                                "gemm/{}/{}/{tag}",
                                kernel.name(),
                                dtype.name()
                            ),
                            gd,
                            gff,
                            gm,
                            None,
                            res.per_item_ns(),
                        );
                    }
                }
            }
        }
        // m_per_expert sweep: how the register tiles amortise as the
        // per-expert row count grows (f32, d=256, plain + gated)
        {
            let (gd, gff) = (256usize, 1024usize);
            let bank_f32 = ExpertBank::new(&Rng::new(77), ge, gd, gff);
            let gated_f32 = gated_bank(78, ge, gd, gff);
            let m_sweep: &[usize] =
                if fast { &[4, 32] } else { &[4, 32, 256] };
            for &m in m_sweep {
                let x = normal_vec(&mut rng, m * gd, 1.0);
                let mut hid = Vec::new();
                let mut out = vec![0.0f32; m * gd];
                for (tag, bank) in
                    [("plain", &bank_f32), ("gated", &gated_f32)]
                {
                    for kernel in Kernel::ALL {
                        let res = b.run_items(
                            &format!(
                                "gemm_m/{}/{tag}/m{m}",
                                kernel.name()
                            ),
                            (m * ge) as f64,
                            &mut || {
                                for ei in 0..ge {
                                    bank.forward_rows_with(
                                        kernel,
                                        ei,
                                        std::hint::black_box(&x),
                                        m,
                                        &mut hid,
                                        &mut out,
                                    );
                                }
                                std::hint::black_box(&out);
                            },
                        );
                        push_gemm_row(
                            &format!(
                                "gemm_m/{}/{tag}",
                                kernel.name()
                            ),
                            gd,
                            gff,
                            m,
                            None,
                            res.per_item_ns(),
                        );
                    }
                }
            }
        }
        // MC×KC×NC tile grid: the blocked kernel at the big shape
        // under a few cache-tile choices (the `--tiles` /
        // LPR_GEMM_TILES knob)
        {
            let (gd, gff) = (256usize, 1024usize);
            let bank = ExpertBank::new(&Rng::new(77), ge, gd, gff);
            let x = normal_vec(&mut rng, gm * gd, 1.0);
            let mut hid = Vec::new();
            let mut out = vec![0.0f32; gm * gd];
            let grid = [
                GemmTiles::new(32, 128, 64),
                GemmTiles::default(),
                GemmTiles::new(128, 512, 256),
            ];
            for tiles in grid {
                let res = b.run_items(
                    &format!("gemm_tiles/blocked/{tiles}"),
                    (gm * ge) as f64,
                    &mut || {
                        for ei in 0..ge {
                            bank.forward_rows_tiled(
                                Kernel::Blocked,
                                tiles,
                                ei,
                                std::hint::black_box(&x),
                                gm,
                                &mut hid,
                                &mut out,
                            );
                        }
                        std::hint::black_box(&out);
                    },
                );
                push_gemm_row(
                    "gemm_tiles/blocked",
                    gd,
                    gff,
                    gm,
                    Some(tiles),
                    res.per_item_ns(),
                );
            }
        }
        write_rows_or_warn("BENCH_gemm.json", &gemm_rows);
    }

    // ---- expert placement: the same pool forward under each
    // placement planner (wall-clock, where load-aware partitioning
    // shows up as pool_forward time), plus the dispatch simulator's
    // modelled serving numbers per planner on a Zipf-skewed routed
    // stream. Emitted as BENCH_placement.json. ----
    {
        let fast = std::env::var("LPR_BENCH_FAST").is_ok();
        let (pd, pdz, pe, pk, pn, pff) =
            (64usize, 16usize, 64usize, 8usize, 1024usize, 256usize);
        let sim_steps = if fast { 16usize } else { 48 };
        let mut placement_rows: Vec<String> = Vec::new();
        let router =
            synthetic_lpr_router("cosine", &mut rng, pd, pdz, pe, pk);
        let bank = ExpertBank::new(&Rng::new(42), pe, pd, pff);
        let mix = MixtureStream::skewed(&mut rng, pd, 1.6);
        let mut hp = Vec::new();
        mix.fill(&mut rng, pn, &mut hp);
        for placement in PlacementPolicy::ALL {
            for workers in [1usize, 4] {
                if workers > cores {
                    continue;
                }
                let mut pool = Engine::builder()
                    .layer(router.plan().clone(), bank.clone())
                    .backend(Backend::Pool { workers })
                    .policy(OverflowPolicy::Drop)
                    .capacity_factor(1.25)
                    .placement(PlacementConfig::with_policy(placement))
                    .build()
                    .expect("valid engine config");
                let res = b.run_items(
                    &format!(
                        "placement/pool_forward/{}/t{workers}/{pn}tok",
                        placement.name()
                    ),
                    pn as f64,
                    &mut || {
                        let out =
                            pool.forward(std::hint::black_box(&hp), pn);
                        std::hint::black_box(out.hidden.len());
                    },
                );
                placement_rows.push(format!(
                    "{{\"name\": \"placement/pool_forward/{}\", \
                     \"n\": {pn}, \"d\": {pd}, \"d_ff\": {pff}, \
                     \"E\": {pe}, \"k\": {pk}, \"workers\": {workers}, \
                     \"ns_per_token\": {:.2}}}",
                    placement.name(),
                    res.per_item_ns()
                ));
            }
            // modelled serving numbers on the same router geometry:
            // mean step latency / stall under this planner at G=8
            let mut srng = Rng::new(23);
            let sr = synthetic_lpr_router(
                "cosine", &mut srng, 32, 16, pe, pk,
            );
            let mut eng = Engine::builder()
                .layer(
                    sr.plan().clone(),
                    ExpertBank::new(&Rng::new(0), pe, 32, 1),
                )
                .backend(Backend::Scoped { threads: 1 })
                .build()
                .expect("valid engine config");
            let smix = MixtureStream::skewed(&mut srng, 32, 1.6);
            let mut sim = DispatchSim::new(SimConfig::default())
                .expect("E=64 over G=8 is a valid sim config");
            sim.set_placement(PlacementConfig {
                policy: placement,
                replan_every: 8,
                bytes_per_expert: 4096,
                ..PlacementConfig::default()
            });
            run_routed_steps(
                &mut eng,
                &smix,
                &mut srng,
                &mut sim,
                sim_steps,
                512,
                OverflowPolicy::Drop,
            );
            let rep = sim.report();
            println!(
                "micro/placement/sim/{}    mean {:>7.0} us  p99 \
                 {:>7.0} us  stall {:.3}  replans {}  migrated {:.0} KiB",
                placement.name(),
                rep.latency_mean_us,
                rep.latency_p99_us,
                rep.stall_frac,
                rep.replans,
                rep.migrated_bytes as f64 / 1024.0
            );
            placement_rows.push(format!(
                "{{\"name\": \"placement/sim/{}\", \"E\": {pe}, \
                 \"k\": {pk}, \"workers\": 8, \"mean_us\": {:.1}, \
                 \"p99_us\": {:.1}, \"stall\": {:.4}, \"replans\": {}, \
                 \"migrated_kib\": {:.0}}}",
                placement.name(),
                rep.latency_mean_us,
                rep.latency_p99_us,
                rep.stall_frac,
                rep.replans,
                rep.migrated_bytes as f64 / 1024.0
            ));
        }
        write_rows_or_warn("BENCH_placement.json", &placement_rows);
    }


    // ---- admission front-end: the compiled matcher vs the naive
    // first-match reference scan on a 16-lane config, plus a short
    // admitted overload run (priority + best-effort lanes at 2x the
    // virtual-clock service rate). Emitted as BENCH_admission.json. ----
    {
        let fast = std::env::var("LPR_BENCH_FAST").is_ok();
        let mut admission_rows: Vec<String> = Vec::new();
        let mut text = String::new();
        for i in 0..15 {
            text.push_str(&format!(
                "lane lane{i}\n  path /v{i}/generate\n  quota 512\n"
            ));
        }
        text.push_str("lane rest\n  quota 512\n");
        let config = AdmissionConfig::parse(&text)
            .expect("16-lane bench config parses");
        let adm = config
            .compile(8, 64)
            .expect("16-lane bench config compiles");
        let metas: Vec<RequestMeta> =
            config.lanes.iter().map(|l| l.example_meta()).collect();
        for (name, compiled) in [("compiled", true), ("reference", false)]
        {
            let res = b.run_items(
                &format!("admission/classify_{name}/16lanes"),
                metas.len() as f64,
                &mut || {
                    for m in &metas {
                        let lane = if compiled {
                            adm.classify(std::hint::black_box(m))
                        } else {
                            adm.classify_reference(
                                std::hint::black_box(m),
                            )
                        };
                        std::hint::black_box(lane);
                    }
                },
            );
            admission_rows.push(format!(
                "{{\"name\": \"admission/classify_{name}\", \
                 \"lanes\": 16, \"ns_per_request\": {:.2}}}",
                res.per_item_ns()
            ));
        }
        // overload run: deterministic virtual clock (every batch takes
        // 500 ticks), so capacity is max_batch / 500 us with no
        // wall-clock measurement needed
        let (ad, adz, ae, ak, aff) =
            (32usize, 16usize, 32usize, 4usize, 64usize);
        let (amax_batch, areq_tokens) = (64usize, 8usize);
        let an_requests = if fast { 128usize } else { 512 };
        let lanes_text = "lane priority\n  path_prefix /priority\n\
                          \x20 quota 256\n  weight 8\n\
                          lane best-effort\n  quota 128\n";
        let lane_cfg = AdmissionConfig::parse(lanes_text)
            .expect("two-lane bench config parses");
        let mut arng = Rng::new(23);
        let arouter =
            synthetic_lpr_router("cosine", &mut arng, ad, adz, ae, ak);
        let abank = ExpertBank::new(&Rng::new(42), ae, ad, aff);
        let amix = MixtureStream::skewed(&mut arng, ad, 1.6);
        let aengine = Engine::builder()
            .layer(arouter.plan().clone(), abank)
            .backend(Backend::Pool { workers: 2 })
            .policy(OverflowPolicy::Drop)
            .capacity_factor(1.25)
            .build()
            .expect("valid engine config");
        let acfg = ServeConfig {
            max_batch: amax_batch,
            max_wait: 200,
            queue_tokens: 8 * amax_batch,
            service_ticks: Some(500),
            ..ServeConfig::default()
        };
        let aadm = lane_cfg
            .compile(ad, amax_batch)
            .expect("two-lane bench config compiles");
        let ametas: Vec<RequestMeta> = {
            let prio = lane_cfg.lanes[0].example_meta();
            let best = lane_cfg.lanes[1].example_meta();
            vec![prio, best.clone(), best.clone(), best]
        };
        let cap_tok_s = amax_batch as f64 / (500.0 / 1_000_000.0);
        let mut art =
            AdmittedRuntime::new(aengine.into_inner(), acfg, aadm);
        run_admitted_open_loop(
            &mut art,
            &amix,
            &mut arng,
            &ametas,
            an_requests,
            areq_tokens,
            2.0 * cap_tok_s,
        );
        let arep = art.report();
        for l in &arep.lanes {
            println!(
                "micro/admission/overload/{}    admitted {:>5}  shed \
                 {:>5}  p50 {:>7.0} us  p99 {:>7.0} us",
                l.name,
                l.admitted,
                l.rejected,
                l.latency_p50_us,
                l.latency_p99_us
            );
            admission_rows.push(format!(
                "{{\"name\": \"admission/overload/{}\", \
                 \"load\": 2.0, \"weight\": {}, \
                 \"admitted\": {}, \"rejected\": {}, \
                 \"p50_us\": {:.1}, \"p99_us\": {:.1}}}",
                l.name,
                l.weight,
                l.admitted,
                l.rejected,
                l.latency_p50_us,
                l.latency_p99_us
            ));
        }
        write_rows_or_warn("BENCH_admission.json", &admission_rows);
    }

    // ---- dispatch simulator ----
    let assignments =
        synthetic_assignments(&mut rng, 2048, 8, 64, 0.7);
    b.run_items("dispatch_sim/step/2048tok", 2048.0, &mut || {
        let mut sim = DispatchSim::new(SimConfig::default())
            .expect("default sim config is valid");
        sim.step(std::hint::black_box(&assignments));
        std::hint::black_box(sim.report());
    });

    // ---- metrics ----
    let load = normal_vec(&mut rng, 512, 1.0)
        .iter()
        .map(|x| x.abs())
        .collect::<Vec<_>>();
    b.run("gini/512experts", || {
        std::hint::black_box(gini(std::hint::black_box(&load)));
    });
    b.run("min_max/512experts", || {
        std::hint::black_box(min_max_ratio(std::hint::black_box(&load)));
    });

    // ---- data pipeline ----
    let mut corpus = ZipfMarkovCorpus::standard(512, 3);
    let batcher = Batcher::new(8, 128);
    b.run_items("corpus/batch_8x128", 1024.0, &mut || {
        std::hint::black_box(batcher.next_synthetic(&mut corpus));
    });

    // ---- json (meta parsing path) ----
    let meta = std::fs::read_to_string(
        lpr::default_art_dir().join("quickstart.meta.json"),
    )
    .unwrap_or_else(|_| "{\"a\": [1,2,3]}".into());
    b.run("json/parse_meta", || {
        std::hint::black_box(Json::parse(std::hint::black_box(&meta)).unwrap());
    });

    // ---- dense matmul bound (router roofline reference) ----
    let a = normal_vec(&mut rng, n * d, 1.0);
    let w = normal_vec(&mut rng, d * e, 1.0);
    b.run_items("linalg/matmul_1024x256x64", n as f64, &mut || {
        std::hint::black_box(matmul(
            std::hint::black_box(&a),
            std::hint::black_box(&w),
            n,
            d,
            e,
        ));
    });

    std::fs::create_dir_all("results").ok();
    b.write_csv(std::path::Path::new("results/bench.csv")).ok();
}
