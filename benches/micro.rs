//! Microbenchmarks for the L3 hot paths (no artifacts needed):
//! serving router (legacy vs compiled plan vs sharded engine) across
//! the full metric library, dispatch simulator, metric kernels, data
//! pipeline, JSON parsing.
//!
//! Run: `cargo bench --bench micro` (results appended to
//! `results/bench.csv`; the routing sweep is also written as
//! machine-readable JSON to `BENCH_router.json`, the dispatch-plan /
//! full expert-forward sweep — scoped *and* persistent-pool — to
//! `BENCH_dispatch.json`, the serving-runtime arrival sweep to
//! `BENCH_serve.json`, and the stacked-model forward sweep — scoped
//! `ModelEngine` vs the persistent pool's `forward_model`, layers
//! {1, 4} — to `BENCH_model.json`, so the perf trajectory is trackable
//! across PRs). Set `LPR_BENCH_FAST=1` for a short smoke run (CI).

use lpr::data::{Batcher, MixtureStream, ZipfMarkovCorpus};
use lpr::dispatch::{
    capacity_for, synthetic_assignments, DispatchPlan, DispatchSim,
    OverflowPolicy, SimConfig,
};
use lpr::experts::ExpertBank;
use lpr::metrics::{gini, min_max_ratio};
use lpr::model::{synthetic_stacked_model, ModelEngine, ModelForward};
use lpr::router::linalg::matmul;
use lpr::router::{
    synthetic_lpr_router, FullForward, RouteBuffers, Router, RouterBatch,
    RouterConfig, RouterKind, RouterParams, ServingEngine, METRICS,
};
use lpr::serve::{
    measure_service_rate, run_open_loop, PoolEngine, ServeConfig,
    ServeRuntime,
};
use lpr::util::bench::{write_json_rows, Bench};
use lpr::util::json::Json;
use lpr::util::rng::Rng;

fn normal_vec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32 * scale).collect()
}

/// One row of BENCH_router.json.
struct RouterRow {
    name: String,
    n: usize,
    d: usize,
    e: usize,
    k: usize,
    threads: usize,
    ns_per_token: f64,
}

/// `lpr::util::bench::write_json_rows` with a warning instead of a
/// hard failure (benches should finish even on a read-only results
/// directory).
fn write_rows_or_warn(path: &str, rows: &[String]) {
    if let Err(e) = write_json_rows(path, rows) {
        eprintln!("warn: could not write {path}: {e}");
    }
}

fn write_router_json(rows: &[RouterRow]) {
    let objs: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{{\"name\": \"{}\", \"n\": {}, \"d\": {}, \"E\": {}, \
                 \"k\": {}, \"threads\": {}, \"ns_per_token\": {:.2}}}",
                r.name, r.n, r.d, r.e, r.k, r.threads, r.ns_per_token
            )
        })
        .collect();
    write_rows_or_warn("BENCH_router.json", &objs);
}

/// One row of BENCH_dispatch.json.
struct DispatchRow {
    name: String,
    n: usize,
    d: usize,
    d_ff: usize,
    e: usize,
    k: usize,
    threads: usize,
    ns_per_token: f64,
}

fn write_dispatch_json(rows: &[DispatchRow]) {
    let objs: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{{\"name\": \"{}\", \"n\": {}, \"d\": {}, \
                 \"d_ff\": {}, \"E\": {}, \"k\": {}, \"threads\": {}, \
                 \"ns_per_token\": {:.2}}}",
                r.name, r.n, r.d, r.d_ff, r.e, r.k, r.threads,
                r.ns_per_token
            )
        })
        .collect();
    write_rows_or_warn("BENCH_dispatch.json", &objs);
}

fn main() {
    let mut b = Bench::new("micro");
    if std::env::var("LPR_BENCH_FAST").is_ok() {
        b.target_s = 0.05; // CI smoke mode
    }
    let mut rng = Rng::new(1);
    let mut router_rows: Vec<RouterRow> = Vec::new();

    // ---- serving router: tokens/s per metric (acceptance config:
    // E=64, d=256, top-8) — legacy per-call path vs compiled plan.
    // NOTE: forward_reference already includes the construction-time
    // projection hoist, so the legacy rows slightly understate the
    // true pre-plan cost (see ROADMAP.md perf-trajectory notes). ----
    let (d, dz, e, k, n) = (256usize, 16usize, 64usize, 8usize, 1024usize);
    let h = normal_vec(&mut rng, n * d, 1.0);
    for metric in METRICS {
        let r = synthetic_lpr_router(metric, &mut rng, d, dz, e, k);
        let res = b.run_items(
            &format!("router_legacy/{metric}/{n}tok"),
            n as f64,
            &mut || {
                std::hint::black_box(r.forward_reference(&h));
            },
        );
        router_rows.push(RouterRow {
            name: format!("legacy/{metric}"),
            n,
            d,
            e,
            k,
            threads: 1,
            ns_per_token: res.per_item_ns(),
        });
        let plan = r.plan().clone();
        let mut buf = RouteBuffers::new();
        let mut out = RouterBatch::new();
        let res = b.run_items(
            &format!("router_plan/{metric}/{n}tok"),
            n as f64,
            &mut || {
                plan.forward_into(
                    std::hint::black_box(&h),
                    &mut buf,
                    &mut out,
                );
                std::hint::black_box(&out);
            },
        );
        router_rows.push(RouterRow {
            name: format!("plan/{metric}"),
            n,
            d,
            e,
            k,
            threads: 1,
            ns_per_token: res.per_item_ns(),
        });
    }

    // ---- sharded serving engine: thread scaling on the LPR hot path --
    let cores = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1);
    for metric in ["cosine", "xattn"] {
        let r = synthetic_lpr_router(metric, &mut rng, d, dz, e, k);
        for threads in [1usize, 2, 4, 8] {
            if threads > cores {
                continue;
            }
            let mut engine =
                ServingEngine::new(r.plan().clone(), threads);
            let mut out = RouterBatch::new();
            let res = b.run_items(
                &format!("router_engine/{metric}/t{threads}/{n}tok"),
                n as f64,
                &mut || {
                    engine.route_into(std::hint::black_box(&h), &mut out);
                    std::hint::black_box(&out);
                },
            );
            router_rows.push(RouterRow {
                name: format!("engine/{metric}"),
                n,
                d,
                e,
                k,
                threads,
                ns_per_token: res.per_item_ns(),
            });
        }
    }

    // vanilla for comparison (d x E matmul dominates)
    let van = Router::new(
        RouterConfig {
            kind: RouterKind::Vanilla,
            d_model: d,
            n_experts: e,
            top_k: k,
            latent_dim: 0,
            metric: "dot".into(),
            unit_ball: false,
            gaussian_sigma: 1.0,
            n_score_heads: 1,
        },
        RouterParams { wg: normal_vec(&mut rng, d * e, 0.1),
                       ..Default::default() },
    );
    {
        let plan = van.plan().clone();
        let mut buf = RouteBuffers::new();
        let mut out = RouterBatch::new();
        let res = b.run_items(
            &format!("router_plan/vanilla/{n}tok"),
            n as f64,
            &mut || {
                plan.forward_into(
                    std::hint::black_box(&h),
                    &mut buf,
                    &mut out,
                );
                std::hint::black_box(&out);
            },
        );
        router_rows.push(RouterRow {
            name: "plan/vanilla".into(),
            n,
            d,
            e,
            k,
            threads: 1,
            ns_per_token: res.per_item_ns(),
        });
    }

    write_router_json(&router_rows);

    // ---- dispatch plans + full expert-parallel forward: per-policy
    // plan-build and route->plan->FFN->combine ns/token, emitted as
    // BENCH_dispatch.json for the cross-PR perf trajectory ----
    {
        let (dd, dz, de, dk, dn, dff) =
            (64usize, 16usize, 64usize, 8usize, 1024usize, 256usize);
        let mut dispatch_rows: Vec<DispatchRow> = Vec::new();
        let router = synthetic_lpr_router("cosine", &mut rng, dd, dz, de, dk);
        let bank = ExpertBank::new(&lpr::util::rng::Rng::new(42), de, dd, dff);
        let mix = MixtureStream::skewed(&mut rng, dd, 1.6);
        let mut hd = Vec::new();
        mix.fill(&mut rng, dn, &mut hd);
        let mut engine = ServingEngine::new(router.plan().clone(), 1);
        let mut batch = RouterBatch::new();
        engine.route_into(&hd, &mut batch);
        let cap = capacity_for(batch.topk_idx.len(), de, 1.0);
        for policy in OverflowPolicy::ALL {
            let mut plan = DispatchPlan::new();
            let res = b.run_items(
                &format!("dispatch_plan/{}/{dn}tok", policy.name()),
                dn as f64,
                &mut || {
                    plan.compile_batch(
                        std::hint::black_box(&batch),
                        cap,
                        policy,
                    );
                    std::hint::black_box(&plan);
                },
            );
            dispatch_rows.push(DispatchRow {
                name: format!("plan_build/{}", policy.name()),
                n: dn,
                d: dd,
                d_ff: dff,
                e: de,
                k: dk,
                threads: 1,
                ns_per_token: res.per_item_ns(),
            });
            for threads in [1usize, 4] {
                if threads > cores {
                    continue;
                }
                let mut eng =
                    ServingEngine::new(router.plan().clone(), threads);
                let mut ff = FullForward::new();
                let res = b.run_items(
                    &format!(
                        "dispatch_full/{}/t{threads}/{dn}tok",
                        policy.name()
                    ),
                    dn as f64,
                    &mut || {
                        eng.forward_full(
                            std::hint::black_box(&hd),
                            &bank,
                            1.0,
                            policy,
                            &mut ff,
                        );
                        std::hint::black_box(&ff);
                    },
                );
                dispatch_rows.push(DispatchRow {
                    name: format!("full_forward/{}", policy.name()),
                    n: dn,
                    d: dd,
                    d_ff: dff,
                    e: de,
                    k: dk,
                    threads,
                    ns_per_token: res.per_item_ns(),
                });
                // persistent pool vs scoped threads on the same batch:
                // the spawn-per-batch fixed cost this PR removes
                let mut pool = PoolEngine::new(
                    router.plan().clone(),
                    bank.clone(),
                    threads,
                );
                let mut pf = FullForward::new();
                let res = b.run_items(
                    &format!(
                        "pool_full/{}/t{threads}/{dn}tok",
                        policy.name()
                    ),
                    dn as f64,
                    &mut || {
                        pool.forward_full(
                            std::hint::black_box(&hd),
                            1.0,
                            policy,
                            &mut pf,
                        );
                        std::hint::black_box(&pf);
                    },
                );
                dispatch_rows.push(DispatchRow {
                    name: format!("pool_forward/{}", policy.name()),
                    n: dn,
                    d: dd,
                    d_ff: dff,
                    e: de,
                    k: dk,
                    threads,
                    ns_per_token: res.per_item_ns(),
                });
            }
        }
        write_dispatch_json(&dispatch_rows);
    }

    // ---- serving runtime: open-loop arrival sweep through the
    // persistent pool + micro-batch queue, emitted as BENCH_serve.json
    // (policy × workers × arrival-rate -> p50/p99/throughput) ----
    {
        let fast = std::env::var("LPR_BENCH_FAST").is_ok();
        let (sd, sdz, se, sk, sff) = (32usize, 16usize, 64usize, 4usize, 64usize);
        let (req_tokens, max_batch) = (32usize, 256usize);
        let n_requests = if fast { 64 } else { 256 };
        let workers_sweep: Vec<usize> =
            [1usize, 4].iter().cloned().filter(|&w| w <= cores).collect();
        let mut serve_rows: Vec<String> = Vec::new();
        for &workers in &workers_sweep {
            let mut rng = Rng::new(23);
            let router =
                synthetic_lpr_router("cosine", &mut rng, sd, sdz, se, sk);
            let bank = ExpertBank::new(&Rng::new(42), se, sd, sff);
            let mix = MixtureStream::skewed(&mut rng, sd, 1.6);
            let mut cal = PoolEngine::new(
                router.plan().clone(),
                bank.clone(),
                workers,
            );
            let cap_tok_s = measure_service_rate(
                &mut cal,
                &mix,
                &mut rng,
                max_batch,
                3,
                1.25,
                OverflowPolicy::Drop,
            );
            drop(cal);
            for policy in OverflowPolicy::ALL {
                for load in [0.5f64, 2.0] {
                    let mut rng = Rng::new(23);
                    let router = synthetic_lpr_router(
                        "cosine", &mut rng, sd, sdz, se, sk,
                    );
                    let bank = ExpertBank::new(&Rng::new(42), se, sd, sff);
                    let mix = MixtureStream::skewed(&mut rng, sd, 1.6);
                    let cfg = ServeConfig {
                        n_workers: workers,
                        max_batch,
                        max_wait: 2_000,
                        queue_tokens: 8 * max_batch,
                        capacity_factor: 1.25,
                        policy,
                        ..ServeConfig::default()
                    };
                    let mut srv = ServeRuntime::new(
                        router.plan().clone(),
                        bank,
                        cfg,
                    );
                    let t0 = std::time::Instant::now();
                    run_open_loop(
                        &mut srv,
                        &mix,
                        &mut rng,
                        n_requests,
                        req_tokens,
                        load * cap_tok_s,
                    );
                    let wall = t0.elapsed().as_secs_f64();
                    let r = srv.report();
                    println!(
                        "micro/serve/{}/w{workers}/load{load}    \
                         p50 {:>7.0} us  p99 {:>7.0} us  {:>10.0} tok/s \
                         ({} batches, {:.2}s wall)",
                        policy.name(),
                        r.latency_p50_us,
                        r.latency_p99_us,
                        r.throughput_tok_per_s,
                        r.batches,
                        wall
                    );
                    serve_rows.push(r.bench_json_row(
                        policy,
                        workers,
                        load * cap_tok_s,
                        load,
                        req_tokens,
                    ));
                }
            }
        }
        write_rows_or_warn("BENCH_serve.json", &serve_rows);
    }

    // ---- stacked model forward: scoped ModelEngine vs persistent
    // pool, layers {1, 4} x workers {1, 4}, emitted as
    // BENCH_model.json (route -> plan -> FFN -> combine -> residual,
    // per layer) ----
    {
        let (md, mdz, me, mk, mff, mn) =
            (32usize, 16usize, 32usize, 4usize, 64usize, 512usize);
        let mut model_rows: Vec<String> = Vec::new();
        let mut push_row = |name: &str,
                            layers: usize,
                            workers: usize,
                            ns_per_token: f64| {
            model_rows.push(format!(
                "{{\"name\": \"{name}\", \"layers\": {layers}, \
                 \"n\": {mn}, \"d\": {md}, \"d_ff\": {mff}, \
                 \"E\": {me}, \"k\": {mk}, \"workers\": {workers}, \
                 \"ns_per_token\": {ns_per_token:.2}}}"
            ));
        };
        for n_layers in [1usize, 4] {
            let model = synthetic_stacked_model(
                "cosine",
                &Rng::new(2025),
                n_layers,
                md,
                mdz,
                me,
                mk,
                mff,
            );
            let mut rng = Rng::new(7);
            let mix = MixtureStream::skewed(&mut rng, md, 1.6);
            let mut hm = Vec::new();
            mix.fill(&mut rng, mn, &mut hm);
            for workers in [1usize, 4] {
                if workers > cores {
                    continue;
                }
                let mut eng = ModelEngine::new(model.clone(), workers);
                let mut out = ModelForward::new();
                let res = b.run_items(
                    &format!(
                        "model_forward/scoped/L{n_layers}/t{workers}/\
                         {mn}tok"
                    ),
                    mn as f64,
                    &mut || {
                        eng.forward(
                            std::hint::black_box(&hm),
                            1.25,
                            OverflowPolicy::Drop,
                            &mut out,
                        );
                        std::hint::black_box(&out);
                    },
                );
                push_row(
                    &format!("model_forward/scoped/L{n_layers}"),
                    n_layers,
                    workers,
                    res.per_item_ns(),
                );
                let mut pool =
                    PoolEngine::from_model(model.clone(), workers);
                let mut pout = ModelForward::new();
                let res = b.run_items(
                    &format!(
                        "model_forward/pool/L{n_layers}/t{workers}/\
                         {mn}tok"
                    ),
                    mn as f64,
                    &mut || {
                        pool.forward_model(
                            std::hint::black_box(&hm),
                            1.25,
                            OverflowPolicy::Drop,
                            &mut pout,
                        );
                        std::hint::black_box(&pout);
                    },
                );
                push_row(
                    &format!("model_forward/pool/L{n_layers}"),
                    n_layers,
                    workers,
                    res.per_item_ns(),
                );
            }
        }
        write_rows_or_warn("BENCH_model.json", &model_rows);
    }

    // ---- dispatch simulator ----
    let assignments =
        synthetic_assignments(&mut rng, 2048, 8, 64, 0.7);
    b.run_items("dispatch_sim/step/2048tok", 2048.0, &mut || {
        let mut sim = DispatchSim::new(SimConfig::default());
        sim.step(std::hint::black_box(&assignments));
        std::hint::black_box(sim.report());
    });

    // ---- metrics ----
    let load = normal_vec(&mut rng, 512, 1.0)
        .iter()
        .map(|x| x.abs())
        .collect::<Vec<_>>();
    b.run("gini/512experts", || {
        std::hint::black_box(gini(std::hint::black_box(&load)));
    });
    b.run("min_max/512experts", || {
        std::hint::black_box(min_max_ratio(std::hint::black_box(&load)));
    });

    // ---- data pipeline ----
    let mut corpus = ZipfMarkovCorpus::standard(512, 3);
    let batcher = Batcher::new(8, 128);
    b.run_items("corpus/batch_8x128", 1024.0, &mut || {
        std::hint::black_box(batcher.next_synthetic(&mut corpus));
    });

    // ---- json (meta parsing path) ----
    let meta = std::fs::read_to_string(
        lpr::default_art_dir().join("quickstart.meta.json"),
    )
    .unwrap_or_else(|_| "{\"a\": [1,2,3]}".into());
    b.run("json/parse_meta", || {
        std::hint::black_box(Json::parse(std::hint::black_box(&meta)).unwrap());
    });

    // ---- dense matmul bound (router roofline reference) ----
    let a = normal_vec(&mut rng, n * d, 1.0);
    let w = normal_vec(&mut rng, d * e, 1.0);
    b.run_items("linalg/matmul_1024x256x64", n as f64, &mut || {
        std::hint::black_box(matmul(
            std::hint::black_box(&a),
            std::hint::black_box(&w),
            n,
            d,
            e,
        ));
    });

    std::fs::create_dir_all("results").ok();
    b.write_csv(std::path::Path::new("results/bench.csv")).ok();
}
