//! Per-table end-to-end benchmarks: for each paper table, time the AOT
//! train-step / eval-step executables of that table's representative
//! artifacts on the PJRT CPU runtime (state device-resident, exactly the
//! hot loop the `repro` experiments run).
//!
//! The full table *reproductions* (hundreds of steps each) live behind
//! `lpr repro tN`; these benches measure the per-step cost that drives
//! their wall time, so `cargo bench` stays minutes, not hours.
//!
//! Self-skips artifacts that have not been built.

use lpr::coordinator::Trainer;
use lpr::data::{Batcher, ZipfMarkovCorpus};
use lpr::runtime::{CompiledArtifacts, Runtime};
use lpr::util::bench::Bench;

/// (paper table, representative artifacts)
// One representative artifact per table family: PJRT compiles cost
// ~25 s each on this box, so the bench suite samples rather than
// enumerates (the per-step cost within a family varies only with the
// shapes benchmarked here).
const TABLE_ARTIFACTS: &[(&str, &[&str])] = &[
    ("table1", &["t1-qwen3", "t1-qwen3-lpr"]),
    ("table2+4", &["ab-base"]), // tables 2/4 reuse ab-base with lw patches
    ("table3+6+7", &["t7-wasserstein"]),
    ("table5", &["t5-128-8"]),
    ("fig1", &["fig1-lpr"]),
    ("e2e", &["e2e-lm"]),
];

fn main() {
    let art_dir = lpr::default_art_dir();
    if !art_dir.join("manifest.json").exists() {
        eprintln!(
            "SKIP all table benches: no artifacts at {} \
             (run `make artifacts`)",
            art_dir.display()
        );
        return;
    }
    let rt = Runtime::cpu().expect("pjrt cpu");
    let mut b = Bench::new("tables");
    b.target_s = 0.5;
    b.warmup_iters = 1;

    for (table, artifacts) in TABLE_ARTIFACTS {
        for name in *artifacts {
            if !art_dir.join(format!("{name}.meta.json")).exists() {
                eprintln!("SKIP {table}/{name}: artifact not built");
                continue;
            }
            let arts = CompiledArtifacts::load(&rt, &art_dir, name)
                .expect("compile");
            let cfg = arts.meta.config.clone();
            let mut trainer =
                Trainer::new(&rt, &arts, 0, None).expect("init");
            let mut corpus = ZipfMarkovCorpus::standard(cfg.vocab, 1);
            let batcher = Batcher::new(cfg.batch_size, cfg.seq_len);
            let batch = batcher.next_synthetic(&mut corpus);
            let tokens = cfg.batch_size * cfg.seq_len;

            b.run_items(
                &format!("{table}/{name}/train_step"),
                tokens as f64,
                &mut || {
                    trainer.train_step(&batch).expect("step");
                },
            );

            let mut eval_corpus =
                ZipfMarkovCorpus::standard(cfg.vocab, 2);
            b.run_items(
                &format!("{table}/{name}/eval_batch"),
                tokens as f64,
                &mut || {
                    trainer.evaluate(&mut eval_corpus, 1).expect("eval");
                },
            );
        }
    }

    std::fs::create_dir_all("results").ok();
    b.write_csv(std::path::Path::new("results/bench.csv")).ok();
}
