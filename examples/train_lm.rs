//! End-to-end driver (EXPERIMENTS.md §E2E): train the `e2e-lm` MoE
//! transformer (~8M params — the largest practical on this 1-core CPU
//! testbed; see DESIGN.md §Substitutions) for a few hundred steps on the
//! synthetic Zipf-Markov corpus, logging the full loss curve and
//! per-layer load-balance trajectory, then compare against the vanilla
//! router twin (`e2e-lm-vanilla`).
//!
//! Run: `cargo run --release --example train_lm -- [steps] [out_dir]`

use anyhow::Result;
use lpr::coordinator::Trainer;
use lpr::data::ZipfMarkovCorpus;
use lpr::metrics::{ascii_heatmap, gini, min_max_ratio, LoadMatrix};
use lpr::runtime::{CompiledArtifacts, Runtime};
use std::time::Instant;

fn run_one(
    rt: &Runtime,
    name: &str,
    steps_override: Option<usize>,
    out_dir: &std::path::Path,
) -> Result<(f64, f64, f64)> {
    let arts = CompiledArtifacts::load(rt, &lpr::default_art_dir(), name)?;
    let cfg = &arts.meta.config;
    let steps = steps_override.unwrap_or(cfg.total_steps);
    println!(
        "\n=== {name}: {:.2}M params | {} layers x {} experts top-{} | \
         router={} | {} steps",
        arts.meta.param_count as f64 / 1e6,
        cfg.n_layers,
        cfg.n_experts,
        cfg.top_k,
        cfg.router,
        steps
    );

    let mut trainer = Trainer::new(rt, &arts, 0, None)?;
    let mut corpus = ZipfMarkovCorpus::standard(cfg.vocab, 1);
    let loss_idx = arts.meta.metric_idx("loss")?;
    let drop_idx = arts.meta.metric_idx("drop_frac")?;

    // balance trajectory: gini of the last-layer load each step
    let (l, e) = arts.meta.load_shape;
    let mut curve = String::from("step,loss,drop_frac,gini_last_layer\n");
    let t0 = Instant::now();
    let mut step_load = LoadMatrix::new(l, e);
    trainer.train_synthetic(&mut corpus, steps, |m| {
        // trainer.load accumulates; recompute last-step layer gini from
        // cumulative deltas is awkward in the callback — log cumulative.
        if m.step % 25 == 0 || m.step + 1 == steps {
            println!(
                "  step {:>4}/{steps}  loss {:.4}  drop {:.3}  \
                 ({:.2} steps/s)",
                m.step,
                m.values[loss_idx],
                m.values[drop_idx],
                (m.step + 1) as f64 / t0.elapsed().as_secs_f64()
            );
        }
        curve.push_str(&format!(
            "{},{},{},\n",
            m.step, m.values[loss_idx], m.values[drop_idx]
        ));
    })?;
    let dt = t0.elapsed().as_secs_f64();
    let tokens = steps * cfg.batch_size * cfg.seq_len;
    println!(
        "  trained {tokens} tokens in {dt:.1}s \
         ({:.0} tok/s end-to-end)",
        tokens as f64 / dt
    );
    step_load.accumulate(
        &trainer
            .load
            .counts
            .iter()
            .map(|&x| x as f32)
            .collect::<Vec<_>>(),
    );

    let mut held_out = ZipfMarkovCorpus::held_out(cfg.vocab, 1, 990_000);
    let eval = trainer.evaluate(&mut held_out, 8)?;
    println!(
        "  held-out loss {:.4} | GINI {:.3} | min-max {:.4} | drop {:.3}",
        eval.loss,
        eval.load.mean_gini(),
        eval.load.mean_min_max(),
        eval.drop_frac
    );
    println!("{}", ascii_heatmap(&eval.load));

    std::fs::create_dir_all(out_dir)?;
    std::fs::write(out_dir.join(format!("{name}.curve.csv")), curve)?;
    std::fs::write(
        out_dir.join(format!("{name}.train.csv")),
        trainer.history_csv(),
    )?;
    // final cumulative train-load distribution per layer
    let mut lcsv = String::from("layer,expert,count\n");
    for li in 0..l {
        for (ei, v) in trainer.load.layer(li).iter().enumerate() {
            lcsv.push_str(&format!("{li},{ei},{v}\n"));
        }
    }
    std::fs::write(out_dir.join(format!("{name}.load.csv")), lcsv)?;
    let _ = (gini(&trainer.load.layer(0)), min_max_ratio(&trainer.load.layer(0)));
    Ok((eval.loss, eval.load.mean_gini(), eval.load.mean_min_max()))
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps = args.first().and_then(|s| s.parse().ok());
    let out_dir = std::path::PathBuf::from(
        args.get(1).cloned().unwrap_or_else(|| "results/e2e".into()),
    );
    let rt = Runtime::cpu()?;

    let (lpr_loss, lpr_gini, lpr_mm) =
        run_one(&rt, "e2e-lm", steps, &out_dir)?;
    let (van_loss, van_gini, van_mm) =
        run_one(&rt, "e2e-lm-vanilla", steps, &out_dir)?;

    println!("\n=== e2e summary (also in {}) ===", out_dir.display());
    println!("router   | test loss | GINI  | min-max");
    println!("vanilla  | {van_loss:.4}   | {van_gini:.3} | {van_mm:.4}");
    println!("LPR      | {lpr_loss:.4}   | {lpr_gini:.3} | {lpr_mm:.4}");
    println!(
        "GINI reduction: {:.1}% (paper: 0.70 -> 0.035 ~= 95%)",
        100.0 * (van_gini - lpr_gini) / van_gini.max(1e-9)
    );
    Ok(())
}
