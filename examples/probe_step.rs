//! Temporary probe: time artifact compile + train_step under xla 0.5.1.
use lpr::coordinator::Trainer;
use lpr::data::{Batcher, ZipfMarkovCorpus};
use lpr::runtime::{CompiledArtifacts, Runtime};
use std::time::Instant;

fn main() {
    let name = std::env::args().nth(1).unwrap_or("t1-mixtral".into());
    let rt = Runtime::cpu().unwrap();
    let t0 = Instant::now();
    let arts = CompiledArtifacts::load(&rt, &lpr::default_art_dir(), &name).unwrap();
    println!("compile all: {:.1}s", t0.elapsed().as_secs_f64());
    let t0 = Instant::now();
    let mut trainer = Trainer::new(&rt, &arts, 0, None).unwrap();
    println!("init: {:.1}s", t0.elapsed().as_secs_f64());
    let (b, t) = arts.meta.batch_shape;
    let mut corpus = ZipfMarkovCorpus::standard(arts.meta.config.vocab, 1);
    let batch = Batcher::new(b, t).next_synthetic(&mut corpus);
    for i in 0..3 {
        let t0 = Instant::now();
        trainer.train_step(&batch).unwrap();
        println!("step {i}: {:.2}s", t0.elapsed().as_secs_f64());
    }
    let t0 = Instant::now();
    trainer.evaluate(&mut corpus, 1).unwrap();
    println!("eval: {:.2}s", t0.elapsed().as_secs_f64());
}
