//! Serving study: what near-perfect load balance buys at inference time.
//!
//! Sweeps routing skew through the expert-parallel dispatch simulator
//! (64 experts on 8 virtual devices, top-8, finite expert capacity) and
//! reports throughput / tail latency / drops / utilization — the
//! quantitative version of the paper's "hardware-software mismatch"
//! argument (§1). The two endpoints of the sweep bracket the paper's
//! measured routers: vanilla (GINI ~0.7) vs LPR (GINI ~0.04).
//!
//! Part 2 routes *real* clustered tokens through the engine facade
//! (`Engine::builder()` over a compiled `RouterPlan`, scoped backend)
//! and dispatches the flat routed batches into the same simulator —
//! the end-to-end serving path with no synthetic assignment shortcut.
//!
//! Part 3 runs the **full expert-parallel data path** on a skewed
//! stream: route → compile a capacity-binned `DispatchPlan` → real
//! expert FFN compute → gate-weighted combine, sweeping the three
//! overflow policies at capacity factor 1.0 — where overflow policy
//! itself becomes a balancing lever (drops fall, throughput rises,
//! and every token is conserved: routed = computed + dropped).
//!
//! Part 4 is the **serving runtime**: the same data path behind a
//! bounded micro-batching request queue on a *persistent* worker pool
//! (`ServeRuntime`), driven by open-loop Poisson arrivals at a sweep
//! of load fractions of the machine's measured capacity — below
//! saturation the latency percentiles hug the batch service time;
//! past it, queueing delay takes over and p99 runs away.
//!
//! Part 5 serves a **whole model stack**: an L=4 LPR model (the shape
//! the trainer trains — per-layer routers and expert banks) through
//! the layered simulator and the persistent-pool runtime, reporting
//! balance *per layer* exactly as the paper plots it — one imbalanced
//! layer stalls the whole stack under the sequential straggler model.
//!
//! Run: `cargo run --release --example serving_sim`

use lpr::data::MixtureStream;
use lpr::dispatch::{
    run_full_steps, run_routed_steps, synthetic_assignments,
    DispatchSim, OverflowPolicy, SimConfig,
};
use lpr::engine::{Backend, Engine, MoeEngine};
use lpr::experts::ExpertBank;
use lpr::model::{run_model_steps, synthetic_stacked_model};
use lpr::router::synthetic_lpr_router;
use lpr::serve::{
    measure_engine_rate, run_open_loop, ServeConfig, ServeRuntime,
};
use lpr::util::rng::Rng;

fn main() {
    let base = SimConfig {
        n_experts: 64,
        n_devices: 8,
        top_k: 8,
        capacity_factor: 1.25,
        alpha_us: 50.0,
        beta_us: 0.5,
    };
    println!(
        "dispatch sim: {} experts / {} devices / top-{} / cf {}",
        base.n_experts, base.n_devices, base.top_k, base.capacity_factor
    );
    println!(
        "{:<12} {:>7} {:>9} {:>14} {:>12} {:>8} {:>8}",
        "skew", "GINI", "min-max", "tok/s", "p99 us", "drop%", "util"
    );

    let mut baseline_tps = None;
    for &skew in &[0.0, 0.25, 0.5, 0.75, 1.0, 1.5, 2.0] {
        let mut sim = DispatchSim::new(base.clone())
            .expect("64 experts over 8 devices is a valid sim config");
        let mut rng = Rng::new(17);
        for _ in 0..300 {
            let a = synthetic_assignments(
                &mut rng,
                2048,
                base.top_k,
                base.n_experts,
                skew,
            );
            sim.step(&a);
        }
        let r = sim.report();
        let tps = r.throughput_tok_per_s;
        let rel = baseline_tps
            .map(|b: f64| format!(" ({:.2}x)", tps / b))
            .unwrap_or_default();
        if baseline_tps.is_none() {
            baseline_tps = Some(tps);
        }
        println!(
            "{:<12} {:>7.3} {:>9.4} {:>14} {:>12.0} {:>8.2} {:>8.3}",
            format!("zipf {skew}"),
            r.load_gini,
            r.load_min_max,
            format!("{:.0}{rel}", tps),
            r.latency_p99_us,
            100.0 * r.drop_frac,
            r.utilization
        );
    }
    println!(
        "\nreading: a GINI-0.7 router (vanilla baseline territory) loses \
         throughput,\nblows up p99 latency and drops tokens; the GINI~0 \
         end is where LPR operates."
    );

    // ---- part 2: compiled routing engine -> dispatch, end to end ----
    let threads = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1)
        .min(8);
    let (d, dz) = (64usize, 16usize);
    println!(
        "\nrouted dispatch: compiled engine, {} experts top-{}, \
         {threads} threads",
        base.n_experts, base.top_k
    );
    println!(
        "{:<12} {:>7} {:>12} {:>14} {:>12} {:>8}",
        "metric", "GINI", "route ns/tok", "tok/s", "p99 us", "util"
    );
    for metric in ["cosine", "gaussian", "wasserstein"] {
        let mut rng = Rng::new(17);
        let router = synthetic_lpr_router(
            metric, &mut rng, d, dz, base.n_experts, base.top_k,
        );
        // routing-only: the FFN stage never runs, so a 1-wide
        // placeholder bank satisfies the facade's stack shape
        let mut engine = Engine::builder()
            .layer(
                router.plan().clone(),
                ExpertBank::new(&Rng::new(0), base.n_experts, d, 1),
            )
            .backend(Backend::Scoped { threads })
            .build()
            .expect("valid engine config");
        let mut sim = DispatchSim::new(base.clone())
            .expect("64 experts over 8 devices is a valid sim config");
        // Zipf-clustered Gaussian-mixture stream (§2.2.1 assumptions)
        let mix = MixtureStream::standard(&mut rng, d);
        let n_tokens = 2048usize;
        let route_ns = run_routed_steps(
            &mut engine,
            &mix,
            &mut rng,
            &mut sim,
            100,
            n_tokens,
            OverflowPolicy::Drop,
        );
        let r = sim.report();
        println!(
            "{:<12} {:>7.3} {:>12.0} {:>14.0} {:>12.0} {:>8.3}",
            metric,
            r.load_gini,
            route_ns as f64 / (100.0 * n_tokens as f64),
            r.throughput_tok_per_s,
            r.latency_p99_us,
            r.utilization
        );
    }

    // ---- part 3: full data path with real expert FFNs, overflow
    // policies swept at capacity factor 1.0 on a skewed stream ----
    let d_ff = 4 * d;
    let full_cfg = SimConfig {
        capacity_factor: 1.0,
        ..base.clone()
    };
    println!(
        "\nfull expert-parallel path: route -> plan -> FFN({d}x{d_ff}) \
         -> combine, cf 1.0, skewed Zipf(1.6) stream, {threads} threads"
    );
    println!(
        "{:<14} {:>8} {:>9} {:>13} {:>14} {:>12}",
        "policy", "drop%", "reroute%", "fwd ns/tok", "tok/s", "p99 us"
    );
    let (steps, n_tokens) = (50usize, 2048usize);
    for policy in OverflowPolicy::ALL {
        let mut rng = Rng::new(17);
        let router = synthetic_lpr_router(
            "cosine", &mut rng, d, dz, base.n_experts, base.top_k,
        );
        let bank =
            ExpertBank::new(&Rng::new(42), base.n_experts, d, d_ff);
        // policy and capacity factor live on the builder — one
        // construction, no per-call threading
        let mut engine = Engine::builder()
            .layer(router.plan().clone(), bank)
            .backend(Backend::Scoped { threads })
            .policy(policy)
            .capacity_factor(full_cfg.capacity_factor)
            .build()
            .expect("valid engine config");
        let mut sim = DispatchSim::new(full_cfg.clone())
            .expect("64 experts over 8 devices is a valid sim config");
        let mix = MixtureStream::skewed(&mut rng, d, 1.6);
        let fwd_ns = run_full_steps(
            &mut engine, &mix, &mut rng, &mut sim, steps, n_tokens,
        );
        let r = sim.report();
        // token conservation on the last step's plan
        let plan = &engine.last().layers[0].plan;
        let computed: usize =
            plan.counts.iter().map(|&c| c as usize).sum();
        assert_eq!(computed + plan.n_dropped, n_tokens * base.top_k);
        println!(
            "{:<14} {:>8.2} {:>9.2} {:>13.0} {:>14.0} {:>12.0}",
            policy.name(),
            100.0 * r.drop_frac,
            100.0 * r.reroute_frac,
            fwd_ns as f64 / (steps * n_tokens) as f64,
            r.throughput_tok_per_s,
            r.latency_p99_us
        );
    }
    println!(
        "\nreading: at cf 1.0 the overflow policy is itself a balancing \
         lever — falling\nthrough to a spare expert (next-choice) or the \
         least-loaded one keeps tokens\nthat greedy drop discards, at \
         identical routed load."
    );

    // ---- part 4: persistent-pool serving runtime — request queue,
    // micro-batching, open-loop arrival sweep ----
    let (sd, sdz, se, sk, sff) = (32usize, 16usize, 64usize, 4usize, 64);
    let (req_tokens, max_batch, n_requests) = (32usize, 256usize, 256usize);
    let pool_workers = threads.min(4);
    let build_pool = |seed: u64, workers: usize| {
        let mut rng = Rng::new(seed);
        let router =
            synthetic_lpr_router("cosine", &mut rng, sd, sdz, se, sk);
        let bank = ExpertBank::new(&Rng::new(42), se, sd, sff);
        Engine::builder()
            .layer(router.plan().clone(), bank)
            .backend(Backend::Pool { workers })
            .policy(OverflowPolicy::LeastLoaded)
            .capacity_factor(1.25)
            .build()
            .expect("valid engine config")
    };
    let mut rng = Rng::new(23);
    // burn the router draw so this mix matches the per-load cells'
    // streams (identical seed discipline to the pre-facade version)
    let _ = synthetic_lpr_router("cosine", &mut rng, sd, sdz, se, sk);
    let mix = MixtureStream::skewed(&mut rng, sd, 1.6);
    let mut cal = build_pool(23, pool_workers);
    let cap_tok_s =
        measure_engine_rate(&mut cal, &mix, &mut rng, max_batch, 3);
    drop(cal);
    println!(
        "\nserving runtime: persistent pool ({pool_workers} workers, \
         least-loaded policy),\n{req_tokens}-token requests, max_batch \
         {max_batch}, max_wait 2ms; measured capacity \
         {cap_tok_s:.0} tok/s"
    );
    println!(
        "{:<8} {:>12} {:>9} {:>9} {:>9} {:>14} {:>9} {:>9}",
        "load", "rate tok/s", "batches", "p50 us", "p99 us",
        "tok/s served", "win-GINI", "rejected"
    );
    for load in [0.4f64, 0.8, 1.6] {
        let mut rng = Rng::new(23);
        let engine = build_pool(23, pool_workers);
        // burn the router draw: identical stream per cell
        let _ = synthetic_lpr_router("cosine", &mut rng, sd, sdz, se, sk);
        let mix = MixtureStream::skewed(&mut rng, sd, 1.6);
        let cfg = ServeConfig {
            max_batch,
            max_wait: 2_000,
            queue_tokens: 8 * max_batch,
            ..ServeConfig::default()
        };
        let mut srv = ServeRuntime::with_engine(engine.into_inner(), cfg);
        run_open_loop(
            &mut srv,
            &mix,
            &mut rng,
            n_requests,
            req_tokens,
            load * cap_tok_s,
        );
        let r = srv.report();
        println!(
            "{:<8} {:>12.0} {:>9} {:>9.0} {:>9.0} {:>14.0} {:>9.3} \
             {:>9}",
            format!("{load}x"),
            load * cap_tok_s,
            r.batches,
            r.latency_p50_us,
            r.latency_p99_us,
            r.throughput_tok_per_s,
            r.window_gini,
            r.rejected
        );
    }
    println!(
        "\nreading: the pool's workers persist across batches (no \
         per-batch thread spawn),\nand the micro-batcher turns a \
         request stream into full batches — below\nsaturation p50 sits \
         near the batch service time; past it, queueing delay\n\
         dominates the tail exactly as the queueing model predicts."
    );

    // ---- part 5: whole model stack — L=4 per-layer routers + expert
    // banks through the layered simulator, balance resolved per layer
    let (n_layers, md, mdz, me, mk, mff) =
        (4usize, 32usize, 16usize, 32usize, 4usize, 64usize);
    let model = synthetic_stacked_model(
        "cosine",
        &Rng::new(2025),
        n_layers,
        md,
        mdz,
        me,
        mk,
        mff,
    );
    let mut engine = Engine::builder()
        .model(model.clone())
        .backend(Backend::Scoped { threads: threads.min(4) })
        .policy(OverflowPolicy::Drop)
        .capacity_factor(1.25)
        .build()
        .expect("valid engine config");
    let mut sim = DispatchSim::new_layered(
        SimConfig {
            n_experts: me,
            top_k: mk,
            capacity_factor: 1.25,
            ..base.clone()
        },
        n_layers,
    )
    .expect("32 experts over 8 devices is a valid sim config");
    let mut rng = Rng::new(2025);
    let mix = MixtureStream::skewed(&mut rng, md, 1.6);
    let fwd_ns =
        run_model_steps(&mut engine, &mix, &mut rng, &mut sim, 50, 1024);
    let r = sim.report();
    println!(
        "\nmodel serving: {n_layers}-layer LPR stack ({me} experts \
         top-{mk}), stacked forward {:.0} ns/token,\nstep latency = sum \
         of per-layer stragglers (p99 {:.0} us)",
        fwd_ns as f64 / (50.0 * 1024.0),
        r.latency_p99_us
    );
    println!("{:<7} {:>9} {:>9} {:>9}", "layer", "win-GINI", "min-max", "cv");
    for lb in &r.layers {
        println!(
            "L{:<6} {:>9.4} {:>9.4} {:>9.3}",
            lb.layer, lb.gini, lb.min_max, lb.cv
        );
    }
    // the pool backend serves the identical stack bit-for-bit — under
    // the facade, swapping backends is a one-word change
    let mut pool = Engine::builder()
        .model(model)
        .backend(Backend::Pool { workers: 2 })
        .policy(OverflowPolicy::Drop)
        .capacity_factor(1.25)
        .build()
        .expect("valid engine config");
    let mut h = Vec::new();
    mix.fill(&mut rng, 256, &mut h);
    let scoped_hidden = engine.forward(&h, 256).hidden.to_vec();
    assert_eq!(scoped_hidden, pool.forward(&h, 256).hidden);
    println!(
        "\nreading: per-layer balance is what the paper's per-layer \
         plots measure; the\npool backend serves the identical stack \
         bit-for-bit (asserted above) with\nno per-batch thread spawns \
         — `lpr serve --ckpt` runs this path on real\ntraining \
         checkpoints via the pure-Rust bridge."
    );
}
