//! Serving study: what near-perfect load balance buys at inference time.
//!
//! Sweeps routing skew through the expert-parallel dispatch simulator
//! (64 experts on 8 virtual devices, top-8, finite expert capacity) and
//! reports throughput / tail latency / drops / utilization — the
//! quantitative version of the paper's "hardware-software mismatch"
//! argument (§1). The two endpoints of the sweep bracket the paper's
//! measured routers: vanilla (GINI ~0.7) vs LPR (GINI ~0.04).
//!
//! Part 2 routes *real* clustered tokens through the compiled routing
//! engine (`RouterPlan` on a sharded `ServingEngine`) and dispatches
//! the flat routed batches into the same simulator — the end-to-end
//! serving path with no synthetic assignment shortcut.
//!
//! Run: `cargo run --release --example serving_sim`

use lpr::data::MixtureStream;
use lpr::dispatch::{
    run_routed_steps, synthetic_assignments, DispatchSim, SimConfig,
};
use lpr::router::{synthetic_lpr_router, ServingEngine};
use lpr::util::rng::Rng;

fn main() {
    let base = SimConfig {
        n_experts: 64,
        n_devices: 8,
        top_k: 8,
        capacity_factor: 1.25,
        alpha_us: 50.0,
        beta_us: 0.5,
    };
    println!(
        "dispatch sim: {} experts / {} devices / top-{} / cf {}",
        base.n_experts, base.n_devices, base.top_k, base.capacity_factor
    );
    println!(
        "{:<12} {:>7} {:>9} {:>14} {:>12} {:>8} {:>8}",
        "skew", "GINI", "min-max", "tok/s", "p99 us", "drop%", "util"
    );

    let mut baseline_tps = None;
    for &skew in &[0.0, 0.25, 0.5, 0.75, 1.0, 1.5, 2.0] {
        let mut sim = DispatchSim::new(base.clone());
        let mut rng = Rng::new(17);
        for _ in 0..300 {
            let a = synthetic_assignments(
                &mut rng,
                2048,
                base.top_k,
                base.n_experts,
                skew,
            );
            sim.step(&a);
        }
        let r = sim.report();
        let tps = r.throughput_tok_per_s;
        let rel = baseline_tps
            .map(|b: f64| format!(" ({:.2}x)", tps / b))
            .unwrap_or_default();
        if baseline_tps.is_none() {
            baseline_tps = Some(tps);
        }
        println!(
            "{:<12} {:>7.3} {:>9.4} {:>14} {:>12.0} {:>8.2} {:>8.3}",
            format!("zipf {skew}"),
            r.load_gini,
            r.load_min_max,
            format!("{:.0}{rel}", tps),
            r.latency_p99_us,
            100.0 * r.drop_frac,
            r.utilization
        );
    }
    println!(
        "\nreading: a GINI-0.7 router (vanilla baseline territory) loses \
         throughput,\nblows up p99 latency and drops tokens; the GINI~0 \
         end is where LPR operates."
    );

    // ---- part 2: compiled routing engine -> dispatch, end to end ----
    let threads = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1)
        .min(8);
    let (d, dz) = (64usize, 16usize);
    println!(
        "\nrouted dispatch: compiled engine, {} experts top-{}, \
         {threads} threads",
        base.n_experts, base.top_k
    );
    println!(
        "{:<12} {:>7} {:>12} {:>14} {:>12} {:>8}",
        "metric", "GINI", "route ns/tok", "tok/s", "p99 us", "util"
    );
    for metric in ["cosine", "gaussian", "wasserstein"] {
        let mut rng = Rng::new(17);
        let router = synthetic_lpr_router(
            metric, &mut rng, d, dz, base.n_experts, base.top_k,
        );
        let mut engine = ServingEngine::new(router.plan().clone(), threads);
        let mut sim = DispatchSim::new(base.clone());
        // Zipf-clustered Gaussian-mixture stream (§2.2.1 assumptions)
        let mix = MixtureStream::standard(&mut rng, d);
        let n_tokens = 2048usize;
        let route_ns = run_routed_steps(
            &mut engine, &mix, &mut rng, &mut sim, 100, n_tokens,
        );
        let r = sim.report();
        println!(
            "{:<12} {:>7.3} {:>12.0} {:>14.0} {:>12.0} {:>8.3}",
            metric,
            r.load_gini,
            route_ns as f64 / (100.0 * n_tokens as f64),
            r.throughput_tok_per_s,
            r.latency_p99_us,
            r.utilization
        );
    }
}
