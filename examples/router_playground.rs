//! Router playground: the pure-Rust serving router on cluster-structured
//! activations, across the full §2.4.1 metric library.
//!
//! Builds an LPR router with hypersphere-initialized prototypes, feeds a
//! Gaussian-mixture token stream (the clusterability assumption of
//! §2.2.1, with Zipf-skewed cluster sizes — the imbalanced-frequencies
//! assumption), and prints per-metric load balance + routing throughput
//! for both the legacy per-call path and the compiled `RouterPlan`
//! (reused `RouteBuffers`, flat outputs, partial top-k select). The two
//! paths are asserted identical on every batch. No PJRT needed — this
//! is the zero-dependency serving hot path.
//!
//! Run: `cargo run --release --example router_playground`

use lpr::metrics::{entropy_frac, gini, min_max_ratio};
use lpr::router::{
    synthetic_lpr_router, RouteBuffers, RouterBatch, METRICS,
};
use lpr::util::rng::Rng;
use std::time::Instant;

fn normal_vec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32 * scale).collect()
}

fn main() {
    let (d, dz, e, k) = (64usize, 16usize, 32usize, 4usize);
    let n_tokens = 4096usize;
    let mut rng = Rng::new(2025);

    // Gaussian-mixture stream: 8 clusters, Zipf(1.1) cluster sizes.
    let n_clusters = 8;
    let centers = normal_vec(&mut rng, n_clusters * d, 1.0);
    let weights: Vec<f64> =
        (1..=n_clusters).map(|r| 1.0 / (r as f64).powf(1.1)).collect();
    let mut h = vec![0.0f32; n_tokens * d];
    for t in 0..n_tokens {
        let c = rng.categorical(&weights);
        for j in 0..d {
            h[t * d + j] = centers[c * d + j] + 0.4 * rng.normal() as f32;
        }
    }

    println!(
        "{} tokens from {} Zipf-weighted clusters -> {} experts top-{}",
        n_tokens, n_clusters, e, k
    );
    println!(
        "{:<14} {:>7} {:>9} {:>9} {:>12} {:>12} {:>8}",
        "metric", "GINI", "min-max", "entropy", "plan tok/s",
        "legacy tok/s", "speedup"
    );

    let mut buf = RouteBuffers::new();
    let mut out = RouterBatch::new();
    for metric in METRICS {
        let router = synthetic_lpr_router(metric, &mut rng, d, dz, e, k);
        let plan = router.plan();

        plan.forward_into(&h, &mut buf, &mut out); // warm buffers
        let t0 = Instant::now();
        plan.forward_into(&h, &mut buf, &mut out);
        let dt_plan = t0.elapsed().as_secs_f64();

        let t0 = Instant::now();
        let reference = router.forward_reference(&h);
        let dt_legacy = t0.elapsed().as_secs_f64();

        // the compiled path must agree with the legacy oracle exactly
        let nested = out.clone().into_nested();
        assert_eq!(nested.topk_idx, reference.topk_idx, "{metric}");
        assert_eq!(nested.load, reference.load, "{metric}");

        println!(
            "{:<14} {:>7.3} {:>9.4} {:>9.3} {:>12.0} {:>12.0} {:>7.1}x",
            metric,
            gini(&out.load),
            min_max_ratio(&out.load),
            entropy_frac(&out.load),
            n_tokens as f64 / dt_plan,
            n_tokens as f64 / dt_legacy,
            dt_legacy / dt_plan
        );
    }
    println!(
        "\nhypersphere-initialized prototypes route near-uniformly at \
         init for geometric metrics — the paper's §2.4 initialization \
         argument, reproduced without any training."
    );
}
