//! Router playground: the pure-Rust serving router on cluster-structured
//! activations, across the full §2.4.1 metric library.
//!
//! Builds an LPR router with hypersphere-initialized prototypes, feeds a
//! Gaussian-mixture token stream (the clusterability assumption of
//! §2.2.1, with Zipf-skewed cluster sizes — the imbalanced-frequencies
//! assumption), and prints per-metric load balance + routing throughput.
//! No PJRT needed — this is the zero-dependency serving hot path.
//!
//! Run: `cargo run --release --example router_playground`

use lpr::metrics::{entropy_frac, gini, min_max_ratio};
use lpr::router::{Router, RouterConfig, RouterKind, RouterParams, METRICS};
use lpr::util::rng::Rng;
use std::time::Instant;

fn normal_vec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32 * scale).collect()
}

fn main() {
    let (d, dz, e, k, heads) = (64usize, 16usize, 32usize, 4usize, 4usize);
    let n_tokens = 4096usize;
    let mut rng = Rng::new(2025);

    // Gaussian-mixture stream: 8 clusters, Zipf(1.1) cluster sizes.
    let n_clusters = 8;
    let centers = normal_vec(&mut rng, n_clusters * d, 1.0);
    let weights: Vec<f64> =
        (1..=n_clusters).map(|r| 1.0 / (r as f64).powf(1.1)).collect();
    let mut h = vec![0.0f32; n_tokens * d];
    for t in 0..n_tokens {
        let c = rng.categorical(&weights);
        for j in 0..d {
            h[t * d + j] = centers[c * d + j] + 0.4 * rng.normal() as f32;
        }
    }

    println!(
        "{} tokens from {} Zipf-weighted clusters -> {} experts top-{}",
        n_tokens, n_clusters, e, k
    );
    println!(
        "{:<14} {:>7} {:>9} {:>9} {:>14}",
        "metric", "GINI", "min-max", "entropy", "tokens/s"
    );

    for metric in METRICS {
        // hypersphere prototype init (normalize gaussian rows)
        let mut proto = normal_vec(&mut rng, e * dz, 1.0);
        for i in 0..e {
            let row = &mut proto[i * dz..(i + 1) * dz];
            let norm: f32 = row.iter().map(|x| x * x).sum::<f32>().sqrt();
            row.iter_mut().for_each(|x| *x /= norm);
        }
        let dh = dz / heads;
        let router = Router::new(
            RouterConfig {
                kind: RouterKind::Lpr,
                d_model: d,
                n_experts: e,
                top_k: k,
                latent_dim: dz,
                metric: metric.to_string(),
                unit_ball: true,
                gaussian_sigma: 1.0,
                n_score_heads: heads,
            },
            RouterParams {
                norm: vec![1.0; d],
                w_mu: normal_vec(&mut rng, d * dz, 1.0 / (d as f32).sqrt()),
                b_mu: vec![0.0; dz],
                w_lv: normal_vec(&mut rng, d * dz, 0.01),
                b_lv: vec![-4.0; dz],
                proto_mu: proto,
                proto_lv: vec![-2.0; e * dz],
                wq: normal_vec(&mut rng, heads * dz * dh, 0.3),
                wk: normal_vec(&mut rng, heads * dz * dh, 0.3),
                ..Default::default()
            },
        );

        let t0 = Instant::now();
        let out = router.forward(&h);
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "{:<14} {:>7.3} {:>9.4} {:>9.3} {:>14.0}",
            metric,
            gini(&out.load),
            min_max_ratio(&out.load),
            entropy_frac(&out.load),
            n_tokens as f64 / dt
        );
    }
    println!(
        "\nhypersphere-initialized prototypes route near-uniformly at \
         init for geometric metrics — the paper's §2.4 initialization \
         argument, reproduced without any training."
    );
}
