//! Quickstart: the smallest end-to-end use of the public API.
//!
//! Loads the `quickstart` AOT artifact (built by `make artifacts`),
//! trains a tiny LPR-routed MoE LM on the synthetic Zipf-Markov corpus
//! for 60 steps with the state device-resident, then evaluates held-out
//! loss and prints the per-layer expert-load heatmap with Gini/min-max.
//!
//! Run: `cargo run --release --example quickstart`

use anyhow::Result;
use lpr::coordinator::Trainer;
use lpr::data::ZipfMarkovCorpus;
use lpr::metrics::ascii_heatmap;
use lpr::runtime::{CompiledArtifacts, Runtime};

fn main() -> Result<()> {
    let art_dir = lpr::default_art_dir();
    let rt = Runtime::cpu()?;
    println!("loading + compiling artifacts/quickstart.* ...");
    let arts = CompiledArtifacts::load(&rt, &art_dir, "quickstart")?;
    let cfg = &arts.meta.config;
    println!(
        "model: {} params | {} layers | {} experts, top-{} | router={}",
        arts.meta.param_count, cfg.n_layers, cfg.n_experts, cfg.top_k,
        cfg.router
    );

    let mut trainer = Trainer::new(&rt, &arts, 0, None)?;
    let mut corpus = ZipfMarkovCorpus::standard(cfg.vocab, 1);
    let steps = cfg.total_steps;
    let loss_idx = arts.meta.metric_idx("loss")?;
    trainer.train_synthetic(&mut corpus, steps, |m| {
        if m.step % 10 == 0 || m.step + 1 == steps {
            println!("step {:>3}/{steps}  loss {:.4}", m.step,
                     m.values[loss_idx]);
        }
    })?;

    let mut held_out = ZipfMarkovCorpus::held_out(cfg.vocab, 1, 990_000);
    let eval = trainer.evaluate(&mut held_out, 8)?;
    println!(
        "\nheld-out: loss {:.4} | GINI {:.3} | min-max {:.3} | drop {:.3}",
        eval.loss,
        eval.load.mean_gini(),
        eval.load.mean_min_max(),
        eval.drop_frac
    );
    println!("{}", ascii_heatmap(&eval.load));
    Ok(())
}
