//! Quickstart: the smallest end-to-end use of the public API — the
//! engine facade plus the wall-clock server, pure Rust (no artifacts,
//! no PJRT; for the training quickstart see `examples/train_lm.rs`).
//!
//! 1. Build a 2-layer synthetic LPR model and an [`Engine`] for it via
//!    the one construction path, `Engine::builder()` — backend,
//!    overflow policy, capacity factor, renormalization all in one
//!    place, validated into typed errors.
//! 2. Run one batch through [`MoeEngine::forward`] and read the
//!    per-layer balance telemetry.
//! 3. Serve the same model behind [`Server`]: real wall-clock request
//!    arrivals, background micro-batch flushing, blocking
//!    `enqueue` / `await_completion`.
//!
//! Everything returns through the unified [`lpr::Error`], so `?` works
//! across the engine, queue, and policy layers.
//!
//! Run: `cargo run --release --example quickstart`

use lpr::data::MixtureStream;
use lpr::dispatch::OverflowPolicy;
use lpr::engine::{Backend, Engine, MoeEngine};
use lpr::model::synthetic_stacked_model;
use lpr::serve::{Server, ServeConfig, ServeRuntime};
use lpr::util::rng::Rng;

fn main() -> Result<(), lpr::Error> {
    let (layers, d, dz, e, k, d_ff) = (2usize, 32, 16, 16, 4, 64);
    let model = synthetic_stacked_model(
        "cosine",
        &Rng::new(7),
        layers,
        d,
        dz,
        e,
        k,
        d_ff,
    );

    // ---- 1 + 2: one batch through the facade ----
    let mut engine = Engine::builder()
        .model(model.clone())
        .backend(Backend::Scoped { threads: 2 })
        .policy(OverflowPolicy::LeastLoaded)
        .capacity_factor(1.25)
        .build()?;
    let mut rng = Rng::new(1);
    let mix = MixtureStream::standard(&mut rng, d);
    let mut h = Vec::new();
    mix.fill(&mut rng, 256, &mut h);
    let n_layers = engine.layers();
    let out = engine.forward(&h, 256);
    println!(
        "forward: {} tokens through {n_layers} layers ({} experts \
         top-{k}), residual stream {} floats",
        out.n_tokens,
        e,
        out.hidden.len()
    );
    for lb in engine.balance().per_layer() {
        println!(
            "  layer {}: win-GINI {:.3}  min-max {:.3}",
            lb.layer, lb.gini, lb.min_max
        );
    }

    // ---- 3: the same model behind the wall-clock server ----
    let pool = Engine::builder()
        .model(model)
        .backend(Backend::Pool { workers: 2 })
        .policy(OverflowPolicy::LeastLoaded)
        .capacity_factor(1.25)
        .build()?;
    let cfg = ServeConfig {
        max_batch: 128,
        max_wait: 2_000, // flush a lone request after 2ms
        queue_tokens: 1024,
        ..ServeConfig::default()
    };
    let server = Server::start(ServeRuntime::with_engine(pool.into_inner(), cfg));
    let mut ids = Vec::new();
    for _ in 0..8 {
        mix.fill(&mut rng, 16, &mut h);
        ids.push(server.enqueue(&h)?);
    }
    for id in ids {
        let c = server.await_completion(id);
        println!(
            "request {id}: {} tokens served in {} us (wall-clock)",
            c.n_tokens, c.latency
        );
    }
    let report = server.shutdown();
    println!(
        "server: {} requests / {} tokens in {} batches, p50/p99 \
         {:.0}/{:.0} us, mean win-GINI {:.3}",
        report.requests,
        report.tokens,
        report.batches,
        report.latency_p50_us,
        report.latency_p99_us,
        report.window_gini
    );
    Ok(())
}
