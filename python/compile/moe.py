"""L2: MoE layer — capacity-binned dispatch/combine around the L1 kernel.

GShard-style dense capacity binning: each expert owns a fixed-size bin of
C = ceil(N*k/E * capacity_factor) token slots. Dispatch is a scatter-add
into [E*C, d] (linear in N*k — no [N,E,C] one-hot blow-up), the expert
SwiGLU runs as the Pallas `moe_ffn` kernel over the dense [E, C, d]
tensor, and combine gathers back with the router's top-k weights.

Tokens that overflow an expert's bin are DROPPED (contribute zero), and
the drop fraction is reported — this is precisely the paper's
hardware-software-mismatch cost of imbalanced routing, made visible in
the training metrics; the Rust dispatch simulator models the same
mechanism at serving time.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .configs import Config
from .kernels.vjp import moe_ffn_ad
from .layers import _dense_init, dense_ffn_fwd, init_dense_ffn
from .routers import RouterOut, init_router, router_fwd


def init_moe_layer(key, cfg: Config) -> dict:
    kr, k1, k3, k2, ks = jax.random.split(key, 5)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    # One fused normal draw per stacked-expert tensor: a per-expert
    # jax.random.split loop emits E independent threefry subgraphs, which
    # blows XLA compile time up by minutes at E=64+ (measured: the init
    # module was the only artifact with pathological compile latency).
    def stack_init(k, d_in, d_out):
        w = jax.random.normal(k, (e, d_in, d_out), jnp.float32)
        return w / jnp.sqrt(float(d_in))

    p = {
        "router": init_router(kr, cfg),
        "w1": stack_init(k1, d, f),
        "w3": stack_init(k3, d, f),
        "w2": stack_init(k2, f, d),
    }
    if cfg.n_shared_experts > 0:  # DeepSeek flavor: always-on experts
        p["shared"] = init_dense_ffn(ks, d, f * cfg.n_shared_experts)
    return p


def dispatch_combine(h: jax.Array, rout: RouterOut, cfg: Config,
                     w1, w3, w2) -> Tuple[jax.Array, jax.Array]:
    """Scatter tokens into capacity bins, run experts, gather back.

    h: [N, d]. Returns (y [N, d], drop_frac scalar).
    """
    n, d = h.shape
    e, k, c = cfg.n_experts, cfg.top_k, cfg.capacity

    flat_e = rout.topk_idx.reshape(-1)                     # [N*k]
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.float32)  # [N*k, E]
    # Rank of each (token, slot) within its expert queue (arrival order).
    # associative_scan, NOT jnp.cumsum: xla_extension 0.5.1 (the rust
    # runtime's XLA) lowers cumsum to an O(N^2) reduce_window on CPU —
    # measured 4.6 s for this [8192, 64] scan vs 3.5 ms for the
    # log-depth scan (EXPERIMENTS.md §Perf). The scan is detached:
    # arrival ranks are discrete routing metadata, not a gradient path
    # (combine weights carry the router gradient), and detaching keeps
    # the backward pass free of the reversed scan.
    running = jax.lax.stop_gradient(
        jax.lax.associative_scan(jnp.add, onehot, axis=0))
    pos = jnp.sum((running - 1.0) * onehot, axis=-1)
    pos = pos.astype(jnp.int32)
    valid = (pos < c).astype(h.dtype)                      # [N*k]
    dest = flat_e * c + jnp.minimum(pos, c - 1)            # [N*k]

    h_rep = jnp.repeat(h, k, axis=0)                       # [N*k, d]
    disp = jnp.zeros((e * c, d), h.dtype).at[dest].add(
        h_rep * valid[:, None], mode="drop")
    expert_out = moe_ffn_ad(disp.reshape(e, c, d), w1, w3, w2)
    gathered = expert_out.reshape(e * c, d)[dest]          # [N*k, d]

    w = rout.combine_w.reshape(-1) * valid                 # [N*k]
    y = jnp.sum((gathered * w[:, None]).reshape(n, k, d), axis=1)
    drop_frac = 1.0 - jnp.sum(valid) / (n * k)
    return y, drop_frac


def moe_layer_fwd(p: dict, h: jax.Array, cfg: Config, rng=None,
                  train: bool = True
                  ) -> Tuple[jax.Array, RouterOut, Dict[str, jax.Array]]:
    """h: [N, d] (token-flattened). Returns (y, router_out, stats)."""
    rout = router_fwd(p["router"], h, cfg, rng, train)
    y, drop_frac = dispatch_combine(h, rout, cfg, p["w1"], p["w3"], p["w2"])
    if "shared" in p:
        y = y + dense_ffn_fwd(p["shared"], h)
    return y, rout, {"drop_frac": drop_frac}
