"""L2: training step — AdamW + warmup-stable-decay LR, grad clipping, and
the non-gradient router updates (DeepSeek bias correction, LPR EMA).

The whole update is ONE jitted function so the AOT artifact contains the
entire training step; the Rust coordinator only shuttles device buffers.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .configs import Config
from .model import init_params, forward, total_loss

# Names (and order) of the scalar metrics vector returned by train_step.
METRIC_NAMES = [
    "loss", "total_loss", "div", "align", "kl", "aux",
    "drop_frac", "grad_norm", "lr",
]


def wsd_lr(step: jax.Array, cfg: Config) -> jax.Array:
    """Warmup-stable-decay schedule (paper §3.1): 5% linear warmup,
    stable plateau, cosine decay to min_lr_ratio over the final span."""
    t = step.astype(jnp.float32)
    total = float(cfg.total_steps)
    warm = jnp.maximum(total * cfg.warmup_frac, 1.0)
    stable_end = total * (cfg.warmup_frac + cfg.stable_frac)
    decay_span = jnp.maximum(total - stable_end, 1.0)

    warm_lr = t / warm
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * jnp.clip(
        (t - stable_end) / decay_span, 0.0, 1.0)))
    decay_lr = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    frac = jnp.where(t < warm, warm_lr, jnp.where(t < stable_end, 1.0,
                                                  decay_lr))
    return cfg.lr * frac


def _decay_mask(params):
    """Weight decay on matrices/stacked-expert tensors only (ndim >= 2)."""
    return jax.tree.map(lambda p: float(p.ndim >= 2), params)


def init_state(key, cfg: Config):
    """(params, m, v) — Adam first/second moments zero-initialized."""
    params = init_params(key, cfg)
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    return params, m, v


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gnorm


def _apply_router_updates(params, updates, lw, cfg: Config):
    """Non-gradient updates, applied AFTER Adam (they bypass momentum):
    - DeepSeek aux-free bias: b += u * sign(mean_load - load)
    - LPR EMA prototype adaptation: mu <- (1-a)*mu + a*batch_mean(z)
    """
    for i, upd in enumerate(updates):
        router = params["layers"][i]["moe"]["router"]
        if "bias_delta" in upd:
            router["bias"] = router["bias"] + lw[5] * upd["bias_delta"]
        if "ema_target" in upd:
            alpha = lw[6]
            router["proto_mu"] = ((1.0 - alpha) * router["proto_mu"]
                                  + alpha * upd["ema_target"])
    return params


def train_step(params, m, v, step, lw, tokens, targets, cfg: Config):
    """One fused optimization step.

    Returns (params', m', v', metrics f32[len(METRIC_NAMES)], load [L,E]).
    """
    rng = jax.random.fold_in(jax.random.PRNGKey(20250711), step)

    (tl, out), grads = jax.value_and_grad(total_loss, has_aux=True)(
        params, tokens, targets, cfg, rng, lw)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)

    lr = wsd_lr(step, cfg)
    t = (step + 1).astype(jnp.float32)
    b1, b2 = cfg.adam_b1, cfg.adam_b2
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t
    wd_mask = _decay_mask(params)

    def upd(p, g, mi, vi, dm):
        mi = b1 * mi + (1.0 - b1) * g
        vi = b2 * vi + (1.0 - b2) * g * g
        mhat = mi / bc1
        vhat = vi / bc2
        p = p - lr * (mhat / (jnp.sqrt(vhat) + 1e-8)
                      + cfg.weight_decay * dm * p)
        return p, mi, vi

    flat = jax.tree.map(upd, params, grads, m, v, wd_mask)
    params = jax.tree.map(lambda x: x[0], flat,
                          is_leaf=lambda x: isinstance(x, tuple))
    m = jax.tree.map(lambda x: x[1], flat,
                     is_leaf=lambda x: isinstance(x, tuple))
    v = jax.tree.map(lambda x: x[2], flat,
                     is_leaf=lambda x: isinstance(x, tuple))

    params = _apply_router_updates(params, out.updates, lw, cfg)

    metrics = jnp.stack([
        out.loss, tl, out.losses["div"], out.losses["align"],
        out.losses["kl"], out.losses["aux"], out.drop_frac, gnorm, lr,
    ])
    return params, m, v, metrics, out.load


def eval_step(params, tokens, targets, cfg: Config):
    """Deterministic evaluation (mean latents, no reparam noise)."""
    out = forward(params, tokens, targets, cfg, rng=None, train=False)
    metrics = jnp.stack([out.loss, out.drop_frac])
    return metrics, out.load


def router_only(params, h, cfg: Config):
    """Standalone router pass for the Rust dispatch simulator / fig.1:
    h [N, d] -> (topk_idx [N,k], combine_w [N,k], load [E])."""
    from .routers import router_fwd
    rout = router_fwd(params, h, cfg, rng=None, train=False)
    return rout.topk_idx, rout.combine_w, rout.load
