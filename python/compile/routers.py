"""L2: the routing contribution — vanilla, DeepSeek aux-free, and LPR.

Implements the paper §2.3 (vanilla top-k router with auxiliary
load-balance loss), the DeepSeek-V3 auxiliary-loss-free bias-correction
router [Wang et al. 2024], and the paper's §2.4 Latent Prototype Router:

  R(x) = D(E(x), P)

with a (variational) non-linear encoder `E` into a low-dim latent space,
expert prototypes `P` (optionally hypersphere-initialized and unit-ball
constrained), the full §2.4.1 metric library `D` (computed by the L1
Pallas kernel), and the three LPR regularizers (KL eq.13, diversity
eq.14, alignment eq.15-17) plus the non-gradient EMA prototype update.

All routers share one return contract (`RouterOut`) so the MoE layer and
the train step are router-agnostic. Non-gradient state updates (DeepSeek
bias, LPR EMA) are returned as *proposals* and applied by train.py after
the optimizer step, bypassing Adam.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .configs import Config
from .kernels.vjp import router_scores_ad
from .layers import _dense_init, rms_norm

_EPS = 1e-9


class RouterOut(NamedTuple):
    topk_idx: jax.Array       # [N, k] int32 expert ids
    combine_w: jax.Array      # [N, k] f32 combine weights (sum<=1)
    scores: jax.Array         # [N, E] raw scores
    load: jax.Array           # [E] f32 assignment counts
    losses: Dict[str, jax.Array]   # div/align/kl/aux scalars
    updates: Dict[str, jax.Array]  # non-gradient param update proposals


def manual_top_k(scores: jax.Array, k: int):
    """Iterative-argmax top-k.

    Functionally identical to `jax.lax.top_k` (descending values, ties
    broken toward the lower index) but lowers to plain argmax/select HLO:
    jax >= 0.7 emits a `topk(..., largest=true)` HLO instruction that the
    xla_extension 0.5.1 text parser (the version the rust `xla` crate
    binds) rejects. k is <= 8 everywhere in the paper, so the k-step scan
    costs k reduces — negligible against the expert FFN.
    """
    s = scores
    idxs, vals = [], []
    for _ in range(k):
        i = jnp.argmax(s, axis=-1)
        v = jnp.take_along_axis(s, i[..., None], axis=-1)[..., 0]
        idxs.append(i.astype(jnp.int32))
        vals.append(v)
        mask = jax.nn.one_hot(i, s.shape[-1], dtype=jnp.bool_)
        s = jnp.where(mask, -jnp.inf, s)
    return jnp.stack(vals, -1), jnp.stack(idxs, -1)


def _topk_softmax(scores: jax.Array, k: int):
    """Paper eq.6: softmax over the selected top-k scores only."""
    top_s, top_i = manual_top_k(scores, k)
    w = jax.nn.softmax(top_s, axis=-1)
    return top_i, w


def _load_counts(topk_idx: jax.Array, n_experts: int) -> jax.Array:
    onehot = jax.nn.one_hot(topk_idx, n_experts, dtype=jnp.float32)
    return jnp.sum(onehot, axis=(0, 1))


# --------------------------------------------------------------------------
# Vanilla router (Qwen3MoE / Mixtral baseline): linear keys + top-k softmax
# + Switch-style auxiliary load-balance loss.
# --------------------------------------------------------------------------

def init_vanilla(key, cfg: Config) -> dict:
    return {"wg": _dense_init(key, cfg.d_model, cfg.n_experts)}


def vanilla_fwd(p: dict, h: jax.Array, cfg: Config, rng=None,
                train: bool = True) -> RouterOut:
    del rng, train
    n, _ = h.shape
    e, k = cfg.n_experts, cfg.top_k
    scores = h @ p["wg"]                                  # [N, E] logits
    probs = jax.nn.softmax(scores, axis=-1)
    topk_idx, combine_w = _topk_softmax(scores, k)
    load = _load_counts(topk_idx, e)
    # Switch/GShard aux loss: E * sum_e f_e * P_e  (1.0 at perfect balance)
    f = load / (n * k)
    pbar = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(f * pbar)
    zeros = jnp.zeros((), jnp.float32)
    return RouterOut(topk_idx, combine_w, scores, load,
                     {"div": zeros, "align": zeros, "kl": zeros, "aux": aux},
                     {})


# --------------------------------------------------------------------------
# DeepSeek-V3 auxiliary-loss-free router: sigmoid affinities, a per-expert
# selection bias that is nudged (non-gradient) toward balance.
# --------------------------------------------------------------------------

def init_deepseek(key, cfg: Config) -> dict:
    return {
        "wg": _dense_init(key, cfg.d_model, cfg.n_experts),
        "bias": jnp.zeros((cfg.n_experts,), jnp.float32),
    }


def deepseek_fwd(p: dict, h: jax.Array, cfg: Config, rng=None,
                 train: bool = True) -> RouterOut:
    del rng
    n, _ = h.shape
    e, k = cfg.n_experts, cfg.top_k
    s = jax.nn.sigmoid(h @ p["wg"])                       # [N, E] affinities
    # Bias enters SELECTION only; combine weights come from raw affinities.
    sel = s + p["bias"][None, :]
    _, topk_idx = manual_top_k(sel, k)
    top_s = jnp.take_along_axis(s, topk_idx, axis=-1)
    combine_w = top_s / (jnp.sum(top_s, axis=-1, keepdims=True) + _EPS)
    load = _load_counts(topk_idx, e)
    # Non-gradient bias update proposal: push underloaded experts up.
    # b_e += u * sign(mean_load - load_e); u is a runtime loss weight.
    err = jnp.mean(load) - load
    zeros = jnp.zeros((), jnp.float32)
    return RouterOut(topk_idx, combine_w, s, load,
                     {"div": zeros, "align": zeros, "kl": zeros,
                      "aux": zeros},
                     {"bias_delta": jnp.sign(err)})


# --------------------------------------------------------------------------
# Latent Prototype Router (the paper's contribution).
# --------------------------------------------------------------------------

def init_lpr(key, cfg: Config) -> dict:
    dz = cfg.latent_dim
    ke, km, kv, kp, kq, kk2 = jax.random.split(key, 6)
    p = {
        "norm": jnp.ones((cfg.d_model,), jnp.float32),
        "w_mu": _dense_init(km, cfg.d_model, dz),
        "b_mu": jnp.zeros((dz,), jnp.float32),
        # logvar head starts near sigma ~ exp(-2) so early routing is
        # mean-driven but the variational path is live from step 0.
        "w_lv": _dense_init(kv, cfg.d_model, dz) * 0.1,
        "b_lv": jnp.full((dz,), -4.0, jnp.float32),
    }
    proto = jax.random.normal(kp, (cfg.n_experts, dz), jnp.float32)
    if cfg.hypersphere_init:
        # Hyperspherical init (§2.4): uniform-on-sphere prototypes give
        # unbiased early routing.
        proto = proto / (jnp.linalg.norm(proto, axis=-1, keepdims=True)
                         + _EPS)
    else:
        proto = proto / jnp.sqrt(float(dz))
    p["proto_mu"] = proto
    p["proto_lv"] = jnp.full((cfg.n_experts, dz), -2.0, jnp.float32)
    if cfg.metric == "xattn":
        h, dh = cfg.n_score_heads, max(1, dz // cfg.n_score_heads)
        p["wq"] = jax.random.normal(kq, (h, dz, dh)) / jnp.sqrt(float(dz))
        p["wk"] = jax.random.normal(kk2, (h, dz, dh)) / jnp.sqrt(float(dz))
    del ke
    return p


def encode(p: dict, h: jax.Array):
    """Paper eq.10-12: a = SiLU(Norm(x)); variational heads (mu, logvar)."""
    a = jax.nn.silu(rms_norm(h, p["norm"]))
    mu = a @ p["w_mu"] + p["b_mu"]
    logvar = jnp.clip(a @ p["w_lv"] + p["b_lv"], -8.0, 4.0)
    return mu, logvar


def diversity_loss(kind: str, proto: jax.Array) -> jax.Array:
    """Paper eq.14 + Table 6 variants, on the prototype matrix [E, dz]."""
    e = proto.shape[0]
    if kind == "none":
        return jnp.zeros((), jnp.float32)
    pn = proto / (jnp.linalg.norm(proto, axis=-1, keepdims=True) + _EPS)
    if kind == "orthogonal":
        g = pn @ pn.T
        return jnp.sum((g - jnp.eye(e)) ** 2) / (e * e)
    if kind == "cosine":
        g = jnp.abs(pn @ pn.T) - jnp.eye(e)
        return jnp.sum(jnp.maximum(g, 0.0)) / (e * (e - 1))
    if kind == "euclidean":
        # Pairwise repulsion hinge: penalize prototypes closer than margin.
        d2 = jnp.sum((proto[:, None, :] - proto[None, :, :]) ** 2, -1)
        margin = 1.0
        hinge = jnp.maximum(margin - jnp.sqrt(d2 + _EPS), 0.0) ** 2
        off = 1.0 - jnp.eye(e)
        return jnp.sum(hinge * off) / (e * (e - 1))
    raise ValueError(kind)


def lpr_fwd(p: dict, h: jax.Array, cfg: Config, rng=None,
            train: bool = True) -> RouterOut:
    n, _ = h.shape
    e, k, dz = cfg.n_experts, cfg.top_k, cfg.latent_dim

    mu, logvar = encode(p, h)
    if cfg.variational and train and rng is not None:
        eps = jax.random.normal(rng, mu.shape, mu.dtype)
        z = mu + jnp.exp(0.5 * logvar) * eps
    else:
        z = mu

    proto_mu, proto_lv = p["proto_mu"], p["proto_lv"]
    if cfg.unit_ball:
        # Project prototypes into the unit ball (Appendix A).
        norm = jnp.linalg.norm(proto_mu, axis=-1, keepdims=True)
        proto_mu = proto_mu / jnp.maximum(norm, 1.0)

    wq, wk = p.get("wq"), p.get("wk")
    scores = router_scores_ad(z, logvar, proto_mu, proto_lv, wq, wk,
                              cfg.metric, cfg.gaussian_sigma)

    topk_idx, combine_w = _topk_softmax(scores, k)
    load = _load_counts(topk_idx, e)

    # --- LPR losses -----------------------------------------------------
    # KL eq.13 against N(0, I), mean over tokens.
    kl = 0.5 * jnp.sum(mu**2 + jnp.exp(logvar) - logvar - 1.0, -1)
    l_kl = jnp.mean(kl)
    # Alignment eq.15-17: prototypes chase the (detached) token latents.
    probs = jax.nn.softmax(scores, axis=-1)
    k_agg = probs @ proto_mu
    l_align = jnp.mean(
        jnp.sum((jax.lax.stop_gradient(z) - k_agg) ** 2, -1))
    # Diversity eq.14 on the prototypes.
    l_div = diversity_loss(cfg.diversity, p["proto_mu"])

    # --- EMA prototype adaptation proposal (hard assignment version) ----
    zd = jax.lax.stop_gradient(z)
    assign = jnp.sum(jax.nn.one_hot(topk_idx, e, dtype=zd.dtype), axis=1)
    z_sum = assign.T @ zd                                  # [E, dz]
    cnt = jnp.sum(assign, axis=0)[:, None]                 # [E, 1]
    z_mean = z_sum / jnp.maximum(cnt, 1.0)
    # Where an expert received no tokens, keep its prototype.
    ema_target = jnp.where(cnt > 0, z_mean, p["proto_mu"])

    zeros = jnp.zeros((), jnp.float32)
    return RouterOut(topk_idx, combine_w, scores, load,
                     {"div": l_div, "align": l_align, "kl": l_kl,
                      "aux": zeros},
                     {"ema_target": ema_target})


INIT = {"vanilla": init_vanilla, "deepseek": init_deepseek, "lpr": init_lpr}
FWD = {"vanilla": vanilla_fwd, "deepseek": deepseek_fwd, "lpr": lpr_fwd}


def init_router(key, cfg: Config) -> dict:
    return INIT[cfg.router](key, cfg)


def router_fwd(p: dict, h: jax.Array, cfg: Config,
               rng: Optional[jax.Array] = None,
               train: bool = True) -> RouterOut:
    return FWD[cfg.router](p, h, cfg, rng, train)
