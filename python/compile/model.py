"""L2: MoE transformer language model (Qwen3 / DeepSeek / Mixtral flavors).

Tiny-scale mirrors of the paper's three 0.6B baselines (Appendix A):
every layer is pre-norm attention + MoE FFN; flavor differences:
  - qwen3:    GQA with qk-norm, aux-loss vanilla router (or LPR)
  - deepseek: shared experts + aux-free bias router (or LPR)
  - mixtral:  plain GQA, aux-loss vanilla router (or LPR)
The model returns the LM loss plus everything the paper's evaluation
needs: per-layer expert load histograms, the individual router losses and
the drop fraction of the capacity-binned dispatch.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .configs import Config
from .layers import (attention_fwd, init_attention, rms_norm, rope_tables)
from .moe import init_moe_layer, moe_layer_fwd


class ModelOut(NamedTuple):
    loss: jax.Array                 # scalar LM cross-entropy
    load: jax.Array                 # [L, E] per-layer expert loads
    losses: Dict[str, jax.Array]    # router loss components (mean over L)
    drop_frac: jax.Array            # scalar, mean over layers
    updates: list                   # per-layer non-gradient update dicts


def init_params(key, cfg: Config) -> dict:
    kemb, *kl = jax.random.split(key, 1 + cfg.n_layers)
    params = {
        "embed": jax.random.normal(
            kemb, (cfg.vocab, cfg.d_model), jnp.float32) * 0.02,
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "layers": [],
    }
    for i in range(cfg.n_layers):
        ka, km = jax.random.split(kl[i])
        params["layers"].append({
            "attn_norm": jnp.ones((cfg.d_model,), jnp.float32),
            "attn": init_attention(ka, cfg),
            "ffn_norm": jnp.ones((cfg.d_model,), jnp.float32),
            "moe": init_moe_layer(km, cfg),
        })
    return params


def forward(params: dict, tokens: jax.Array, targets: jax.Array,
            cfg: Config, rng=None, train: bool = True) -> ModelOut:
    """tokens/targets: [B, T] int32. Next-token cross-entropy loss."""
    b, t = tokens.shape
    cos, sin = rope_tables(t, cfg.head_dim, cfg.rope_theta)
    h = params["embed"][tokens]                      # [B, T, d]

    loads, updates = [], []
    acc = {"div": 0.0, "align": 0.0, "kl": 0.0, "aux": 0.0}
    drop = 0.0
    for i, lp in enumerate(params["layers"]):
        a = attention_fwd(lp["attn"], rms_norm(h, lp["attn_norm"]), cfg,
                          cos, sin)
        h = h + a
        hn = rms_norm(h, lp["ffn_norm"]).reshape(b * t, cfg.d_model)
        lrng = None if rng is None else jax.random.fold_in(rng, i)
        y, rout, stats = moe_layer_fwd(lp["moe"], hn, cfg, lrng, train)
        h = h + y.reshape(b, t, cfg.d_model)
        loads.append(rout.load)
        updates.append(rout.updates)
        for k in acc:
            acc[k] = acc[k] + rout.losses[k]
        drop = drop + stats["drop_frac"]

    h = rms_norm(h, params["final_norm"])
    logits = h @ params["embed"].T                   # tied embeddings
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    loss = jnp.mean(nll)

    nl = float(cfg.n_layers)
    losses = {k: v / nl for k, v in acc.items()}
    return ModelOut(loss, jnp.stack(loads), losses, drop / nl, updates)


def total_loss(params: dict, tokens, targets, cfg: Config, rng,
               lw: jax.Array) -> Tuple[jax.Array, ModelOut]:
    """Paper eq.24: L = L_task + beta_rs(b1*div + b2*align + b3*kl) [+ aux].

    `lw` is the runtime loss-weight vector (configs.LOSS_WEIGHTS layout),
    so ablations over weights reuse one compiled artifact.
    """
    out = forward(params, tokens, targets, cfg, rng, train=True)
    reg = lw[0] * (lw[1] * out.losses["div"]
                   + lw[2] * out.losses["align"]
                   + lw[3] * out.losses["kl"])
    aux = lw[4] * out.losses["aux"]
    return out.loss + reg + aux, out
