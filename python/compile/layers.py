"""L2 substrate: transformer building blocks (RMSNorm, RoPE, GQA attention).

Everything is hand-rolled on jnp (no flax/optax) so the lowered HLO has no
framework baggage and the flat-parameter AOT contract stays simple.
Parameters are nested dicts of jnp arrays; `init_*` functions build them,
`*_fwd` functions apply them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .configs import Config


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def _dense_init(key, d_in: int, d_out: int) -> jax.Array:
    scale = 1.0 / jnp.sqrt(d_in)
    return jax.random.normal(key, (d_in, d_out), jnp.float32) * scale


def rope_tables(seq_len: int, head_dim: int, theta: float):
    """Precompute RoPE cos/sin tables [T, head_dim/2]."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    pos = jnp.arange(seq_len, dtype=jnp.float32)
    ang = pos[:, None] * freqs[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: [B, T, H, hd] with hd even; rotate pairs (x1, x2)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[None, :, None, :]
    s = sin[None, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)


def init_attention(key, cfg: Config) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    kq, kk, kv, ko, kn1, kn2 = jax.random.split(key, 6)
    p = {
        "wq": _dense_init(kq, d, cfg.n_heads * hd),
        "wk": _dense_init(kk, d, cfg.n_kv_heads * hd),
        "wv": _dense_init(kv, d, cfg.n_kv_heads * hd),
        "wo": _dense_init(ko, cfg.n_heads * hd, d),
    }
    if cfg.qk_norm:  # qwen3 flavor: per-head-dim RMSNorm on q and k
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def attention_fwd(p: dict, x: jax.Array, cfg: Config,
                  cos: jax.Array, sin: jax.Array) -> jax.Array:
    """Causal grouped-query attention. x: [B, T, d] -> [B, T, d]."""
    b, t, d = x.shape
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, t, nh, hd)
    k = (x @ p["wk"]).reshape(b, t, nkv, hd)
    v = (x @ p["wv"]).reshape(b, t, nkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    # Expand KV heads to query heads (GQA).
    rep = nh // nkv
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    att = jnp.einsum("bthd,bshd->bhts", q, k) / jnp.sqrt(float(hd))
    mask = jnp.tril(jnp.ones((t, t), bool))
    att = jnp.where(mask[None, None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhts,bshd->bthd", att, v).reshape(b, t, nh * hd)
    return out @ p["wo"]


def init_dense_ffn(key, d_model: int, d_ff: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": _dense_init(k1, d_model, d_ff),
        "w3": _dense_init(k2, d_model, d_ff),
        "w2": _dense_init(k3, d_ff, d_model),
    }


def dense_ffn_fwd(p: dict, x: jax.Array) -> jax.Array:
    """SwiGLU FFN (used for DeepSeek-style shared experts)."""
    return (jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])) @ p["w2"]
