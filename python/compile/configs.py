"""Experiment configuration registry.

One `Config` fully determines an AOT artifact set (init/train/eval/router
HLO + meta.json). The preset registry mirrors DESIGN.md's per-experiment
index: every paper table/figure row maps to a preset name here, and the
Rust CLI refers to artifacts by these names.

Scale note: everything is tiny (d_model=128, 2 MoE layers) so that a full
table sweep fits a 1-core CPU budget; see DESIGN.md §Substitutions.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Dict, List

GEOMETRIC_METRICS = ("dot", "cosine", "gaussian", "mahalanobis", "xattn")
DISTRIBUTION_METRICS = ("wasserstein", "kl", "js", "hellinger")
METRICS = GEOMETRIC_METRICS + DISTRIBUTION_METRICS
DIVERSITY_TYPES = ("orthogonal", "cosine", "euclidean", "none")
ROUTERS = ("vanilla", "deepseek", "lpr")
ARCHS = ("qwen3", "deepseek", "mixtral")

# Layout of the runtime loss-weight vector (f32[8] input to train_step).
# Keeping these runtime inputs lets Tables 2/4 (component ablation,
# regularization-strength sweep) reuse ONE compiled artifact.
LOSS_WEIGHTS = [
    "beta_rs",      # 0: global LPR regularization scale (paper: 0.01)
    "beta_div",     # 1: diversity loss weight (paper: 1.0)
    "beta_align",   # 2: alignment loss weight (paper: 0.1)
    "beta_kl",      # 3: KL loss weight (paper: 0.01)
    "aux_coef",     # 4: vanilla aux load-balance loss coef (paper: 1e-3)
    "bias_update",  # 5: DeepSeek aux-free bias update rate
    "ema_alpha",    # 6: (1-lambda) for EMA prototype adaptation; 0 = off
    "spare",        # 7: reserved
]


@dataclass(frozen=True)
class Config:
    """Full model + router + training configuration for one artifact set."""

    name: str
    arch: str = "qwen3"            # qwen3 | deepseek | mixtral
    router: str = "lpr"            # vanilla | deepseek | lpr

    # model
    vocab: int = 512
    d_model: int = 128
    n_layers: int = 2              # all layers are MoE layers
    n_heads: int = 4
    n_kv_heads: int = 2
    head_dim: int = 32
    moe_d_ff: int = 64             # per-expert FFN width
    n_experts: int = 32
    top_k: int = 4
    n_shared_experts: int = 0      # deepseek flavor uses > 0
    capacity_factor: float = 1.5
    qk_norm: bool = False          # qwen3 flavor
    rope_theta: float = 10000.0

    # LPR router
    latent_dim: int = 16
    metric: str = "cosine"         # see METRICS
    n_score_heads: int = 4         # for metric == "xattn"
    diversity: str = "orthogonal"  # see DIVERSITY_TYPES
    variational: bool = True
    hypersphere_init: bool = True
    unit_ball: bool = True
    gaussian_sigma: float = 1.0    # for metric == "gaussian"

    # training
    seq_len: int = 128
    batch_size: int = 8
    lr: float = 1e-3
    min_lr_ratio: float = 0.05
    warmup_frac: float = 0.05
    stable_frac: float = 0.70      # warmup 5% / stable 70% / decay 25%
    weight_decay: float = 0.1
    adam_b1: float = 0.9
    adam_b2: float = 0.95
    grad_clip: float = 1.0
    total_steps: int = 300         # used by the in-graph WSD schedule

    # default runtime loss weights (Rust may override per run)
    beta_rs: float = 0.01
    beta_div: float = 1.0
    beta_align: float = 0.1
    beta_kl: float = 0.01
    aux_coef: float = 1e-3
    bias_update: float = 1e-3
    ema_alpha: float = 0.0

    def __post_init__(self):
        assert self.arch in ARCHS, self.arch
        assert self.router in ROUTERS, self.router
        assert self.metric in METRICS, self.metric
        assert self.diversity in DIVERSITY_TYPES, self.diversity
        assert self.d_model % self.n_heads == 0 or self.head_dim > 0
        assert self.n_heads % self.n_kv_heads == 0
        assert self.top_k <= self.n_experts

    @property
    def tokens_per_batch(self) -> int:
        return self.seq_len * self.batch_size

    @property
    def capacity(self) -> int:
        """Per-expert capacity of the dense dispatch bins."""
        n = self.tokens_per_batch
        cap = int(n * self.top_k / self.n_experts * self.capacity_factor)
        return max(4, cap)

    def default_loss_weights(self) -> List[float]:
        w = [
            self.beta_rs, self.beta_div, self.beta_align, self.beta_kl,
            self.aux_coef, self.bias_update, self.ema_alpha, 0.0,
        ]
        assert len(w) == len(LOSS_WEIGHTS)
        return w

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


# Tiny-scale LPR calibration (see DESIGN.md §Substitutions and
# EXPERIMENTS.md §Calibration): the paper trains 100M-1B tokens with
# beta_rs=0.01; at our ~500x smaller step budget the regularization
# pressure integrates over far fewer updates, so LPR presets default to
# the paper's own Table-4 beta_rs=0.1 operating point and enable the
# paper's EMA prototype adaptation (contribution 3, hard-assignment
# version, lambda=0.7). Measured on quickstart/240 steps: gini
# 0.60->0.067, min-max 0 -> 0.63, test loss unchanged vs beta_rs=0.01.
TINY_LPR = dict(beta_rs=0.1, ema_alpha=0.3)


def _lpr(name: str, **kw) -> Config:
    for k, v in TINY_LPR.items():
        kw.setdefault(k, v)
    return Config(name=name, router="lpr", **kw)


def build_registry() -> Dict[str, Config]:
    """All presets referenced by DESIGN.md's per-experiment index."""
    r: Dict[str, Config] = {}

    def add(cfg: Config):
        assert cfg.name not in r, f"duplicate preset {cfg.name}"
        r[cfg.name] = cfg

    # ---- quickstart / e2e ----------------------------------------------
    add(Config(name="quickstart", n_experts=16, top_k=2, n_layers=2,
               total_steps=60, batch_size=4, **TINY_LPR))
    # e2e driver: the largest model practical on this testbed.
    add(Config(name="e2e-lm", d_model=256, n_layers=4, n_heads=8,
               n_kv_heads=4, head_dim=32, moe_d_ff=128, n_experts=32,
               top_k=4, vocab=512, seq_len=256, batch_size=4,
               total_steps=300, router="lpr", **TINY_LPR))
    add(Config(name="e2e-lm-vanilla", d_model=256, n_layers=4, n_heads=8,
               n_kv_heads=4, head_dim=32, moe_d_ff=128, n_experts=32,
               top_k=4, vocab=512, seq_len=256, batch_size=4,
               total_steps=300, router="vanilla"))

    # ---- Table 1: arch x router ----------------------------------------
    t1 = dict(n_experts=64, top_k=8, total_steps=300)
    add(Config(name="t1-qwen3", arch="qwen3", router="vanilla",
               qk_norm=True, **t1))
    add(Config(name="t1-qwen3-lpr", arch="qwen3", router="lpr",
               qk_norm=True, hypersphere_init=True, **TINY_LPR, **t1))
    add(Config(name="t1-qwen3-lpr-noinit", arch="qwen3", router="lpr",
               qk_norm=True, hypersphere_init=False, **TINY_LPR, **t1))
    add(Config(name="t1-deepseek", arch="deepseek", router="deepseek",
               n_shared_experts=2, **t1))
    add(Config(name="t1-deepseek-lpr", arch="deepseek", router="lpr",
               n_shared_experts=2, hypersphere_init=False, **TINY_LPR,
               **t1))
    add(Config(name="t1-mixtral", arch="mixtral", router="vanilla", **t1))
    add(Config(name="t1-mixtral-lpr", arch="mixtral", router="lpr",
               hypersphere_init=False, **TINY_LPR, **t1))

    # ---- ablation base (Tables 2 & 4 reuse this single artifact) -------
    add(_lpr("ab-base", total_steps=240))

    # ---- Table 3: latent dim -------------------------------------------
    for dz in (4, 8, 16, 32, 64, 128, 256):
        add(_lpr(f"t3-dim{dz}", latent_dim=dz, total_steps=240))

    # ---- Table 5: expert count sweep (tiny-scale mirror: 32..256) ------
    # Paper sweeps 128..512 at 0.6B; we mirror the *ratios* N/k.
    for n, k in ((32, 8), (64, 8), (128, 8), (128, 4), (128, 1)):
        add(_lpr(f"t5-{n}-{k}", n_experts=n, top_k=k, total_steps=240))

    # ---- Table 6: diversity measure ------------------------------------
    for div in ("cosine", "orthogonal", "euclidean"):
        add(_lpr(f"t6-div-{div}", diversity=div, total_steps=240))

    # ---- Table 7: similarity / divergence metric -----------------------
    for m in METRICS:
        if m == "dot":
            continue  # 'dot' is the vanilla baseline, covered by t1
        add(_lpr(f"t7-{m}", metric=m, total_steps=240))

    # ---- Figure 1: per-layer load heatmaps ------------------------------
    add(Config(name="fig1-vanilla", router="vanilla", n_layers=4,
               total_steps=240))
    add(_lpr("fig1-lpr", n_layers=4, total_steps=240))

    return r


REGISTRY = build_registry()


def get(name: str) -> Config:
    if name not in REGISTRY:
        raise KeyError(f"unknown preset '{name}'; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


def main():
    print(json.dumps({k: v.to_json() for k, v in REGISTRY.items()}, indent=1))


if __name__ == "__main__":
    main()
