"""L1 Pallas kernel: fused latent-router score computation (metric library).

Implements the paper's §2.4.1 measurement `D(E(x), P)` for every metric:
geometric (dot, cosine, gaussian kernel, mahalanobis, multi-head
cross-attention) and distributional (Wasserstein-2, KL, JS, Hellinger on
diagonal Gaussians).

The kernel tiles the token stream (grid over N) and pins the full
prototype table in VMEM — at the paper's scale E*d_z <= 512*16 floats
(32 KiB), far below the ~16 MiB VMEM budget, so scores are produced in a
single pass over tokens (bandwidth-bound on the token stream).

All metrics share one kernel body with a *static* metric switch, so each
lowered artifact contains only the ops of its configured metric.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

GEOMETRIC = ("dot", "cosine", "gaussian", "mahalanobis", "xattn")
DISTRIBUTIONAL = ("wasserstein", "kl", "js", "hellinger")
ALL_METRICS = GEOMETRIC + DISTRIBUTIONAL

_EPS = 1e-6


def _pairwise_sq_dist(z, p):
    """[N,dz] x [E,dz] -> [N,E] squared euclidean distances."""
    z2 = jnp.sum(z * z, axis=-1, keepdims=True)          # [N,1]
    p2 = jnp.sum(p * p, axis=-1)[None, :]                # [1,E]
    return jnp.maximum(z2 + p2 - 2.0 * (z @ p.T), 0.0)


def metric_scores(metric: str, z_mu, z_logvar, p_mu, p_logvar,
                  wq=None, wk=None, *, sigma: float = 1.0):
    """Pure-jnp metric math, shared by the kernel body and the ref oracle.

    Shapes: z_mu/z_logvar [N, dz]; p_mu/p_logvar [E, dz];
    wq/wk [H, dz, dh] (xattn only). Returns scores [N, E] where HIGHER is
    a better token-expert match (distances are negated).
    """
    if metric == "dot":
        return z_mu @ p_mu.T
    if metric == "cosine":
        zn = z_mu / (jnp.linalg.norm(z_mu, axis=-1, keepdims=True) + _EPS)
        pn = p_mu / (jnp.linalg.norm(p_mu, axis=-1, keepdims=True) + _EPS)
        return zn @ pn.T
    if metric == "gaussian":
        return jnp.exp(-_pairwise_sq_dist(z_mu, p_mu) / (2.0 * sigma**2))
    if metric == "mahalanobis":
        # Per-expert diagonal precision exp(-p_logvar):
        # dist^2_ne = sum_d (z_nd - p_ed)^2 * prec_ed
        prec = jnp.exp(-p_logvar)                                    # [E,dz]
        z2 = (z_mu * z_mu) @ prec.T                                  # [N,E]
        cross = z_mu @ (p_mu * prec).T                               # [N,E]
        p2 = jnp.sum(p_mu * p_mu * prec, axis=-1)[None, :]           # [1,E]
        return -(z2 - 2.0 * cross + p2)
    if metric == "xattn":
        # Multi-head dot-product attention between token queries and
        # expert keys, averaged over heads (paper eq. 18-19).
        h, dz, dh = wq.shape
        q = jnp.einsum("nd,hde->hne", z_mu, wq)                      # [H,N,dh]
        k = jnp.einsum("md,hde->hme", p_mu, wk)                      # [H,E,dh]
        att = jnp.einsum("hne,hme->hnm", q, k) / jnp.sqrt(float(dh))
        return jnp.mean(att, axis=0)

    # Distributional metrics: diagonal Gaussians N(z_mu, exp(z_logvar)) vs
    # N(p_mu, exp(p_logvar)); scores are negated distances/divergences.
    v1 = jnp.exp(z_logvar)[:, None, :]      # [N,1,dz]
    v2 = jnp.exp(p_logvar)[None, :, :]      # [1,E,dz]
    m1 = z_mu[:, None, :]
    m2 = p_mu[None, :, :]
    dm2 = (m1 - m2) ** 2
    if metric == "wasserstein":
        s1, s2 = jnp.sqrt(v1), jnp.sqrt(v2)
        w2 = jnp.sum(dm2 + (s1 - s2) ** 2, axis=-1)
        return -w2
    if metric == "kl":
        kl = 0.5 * jnp.sum(
            jnp.log(v2 / v1) + (v1 + dm2) / v2 - 1.0, axis=-1)
        return -kl
    if metric == "js":
        # Paper eq. 22 with the mixture moments mu0=(mu1+mu2)/2,
        # sigma0^2=(v1+v2)/2, summed over dims.
        v0 = 0.5 * (v1 + v2)
        m0 = 0.5 * (m1 + m2)
        js = 0.25 * jnp.sum(
            jnp.log((v1 + v2) ** 2 / (4.0 * v1 * v2))
            + (v1 + (m1 - m0) ** 2) / v0
            + (v2 + (m2 - m0) ** 2) / v0
            - 2.0, axis=-1)
        return -js
    if metric == "hellinger":
        # Squared Hellinger distance; per-dim product form of eq. 23
        # computed in log space for stability.
        s1, s2 = jnp.sqrt(v1), jnp.sqrt(v2)
        log_bc = jnp.sum(
            0.5 * jnp.log(2.0 * s1 * s2 / (v1 + v2) + _EPS)
            - 0.25 * dm2 / (v1 + v2), axis=-1)
        return -(1.0 - jnp.exp(log_bc))
    raise ValueError(f"unknown metric {metric}")


def _make_kernel(metric: str, sigma: float, has_attn: bool):
    if has_attn:
        def kernel(zm_ref, zv_ref, pm_ref, pv_ref, wq_ref, wk_ref, o_ref):
            o_ref[...] = metric_scores(
                metric, zm_ref[...], zv_ref[...], pm_ref[...], pv_ref[...],
                wq_ref[...], wk_ref[...], sigma=sigma)
    else:
        def kernel(zm_ref, zv_ref, pm_ref, pv_ref, o_ref):
            o_ref[...] = metric_scores(
                metric, zm_ref[...], zv_ref[...], pm_ref[...], pv_ref[...],
                sigma=sigma)
    return kernel


def _pick_n_block(n: int, n_block=None) -> int:
    # CPU-interpret default: one grid step (each interpret-mode grid
    # iteration costs ~ms of while-loop overhead; see moe_ffn.py).
    # For the TPU-faithful schedule pass n_block=128/256.
    if n_block is not None:
        assert n % n_block == 0, (n, n_block)
        return n_block
    return n


@functools.partial(
    jax.jit, static_argnames=("metric", "sigma", "n_block", "interpret"))
def router_scores(z_mu, z_logvar, p_mu, p_logvar, wq=None, wk=None, *,
                  metric: str = "cosine", sigma: float = 1.0,
                  n_block: int | None = None,
                  interpret: bool = True) -> jax.Array:
    """Compute [N, E] token-expert scores with the configured metric."""
    assert metric in ALL_METRICS, metric
    n, dz = z_mu.shape
    e = p_mu.shape[0]
    nb = _pick_n_block(n, n_block)
    has_attn = metric == "xattn"
    if has_attn:
        assert wq is not None and wk is not None

    in_specs = [
        pl.BlockSpec((nb, dz), lambda i: (i, 0)),   # z_mu: tiled over tokens
        pl.BlockSpec((nb, dz), lambda i: (i, 0)),   # z_logvar
        pl.BlockSpec((e, dz), lambda i: (0, 0)),    # p_mu: pinned in VMEM
        pl.BlockSpec((e, dz), lambda i: (0, 0)),    # p_logvar
    ]
    args = [z_mu, z_logvar, p_mu, p_logvar]
    if has_attn:
        h, _, dh = wq.shape
        in_specs += [
            pl.BlockSpec((h, dz, dh), lambda i: (0, 0, 0)),
            pl.BlockSpec((h, dz, dh), lambda i: (0, 0, 0)),
        ]
        args += [wq, wk]

    return pl.pallas_call(
        _make_kernel(metric, sigma, has_attn),
        grid=(n // nb,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((nb, e), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, e), z_mu.dtype),
        interpret=interpret,
    )(*args)
