"""L1: Pallas kernels for the MoE hot-spots (interpret=True on CPU)."""
from .moe_ffn import moe_ffn
from .scores import ALL_METRICS, DISTRIBUTIONAL, GEOMETRIC, router_scores

__all__ = ["moe_ffn", "router_scores", "ALL_METRICS", "GEOMETRIC",
           "DISTRIBUTIONAL"]
