"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness signal: every kernel must match its oracle
to float32 tolerance across a hypothesis sweep of shapes (see
python/tests/test_kernels.py). No pallas entry points here — only the
shared metric math, evaluated directly (untiled).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .scores import metric_scores


def moe_ffn_ref(x, w1, w3, w2):
    """Reference grouped SwiGLU FFN: einsum over the expert dimension."""
    gate = jnp.einsum("ecd,edf->ecf", x, w1)
    up = jnp.einsum("ecd,edf->ecf", x, w3)
    act = jax.nn.silu(gate) * up
    return jnp.einsum("ecf,efd->ecd", act, w2)


def router_scores_ref(z_mu, z_logvar, p_mu, p_logvar, wq=None, wk=None, *,
                      metric="cosine", sigma: float = 1.0):
    """Reference metric scores — direct (untiled) evaluation."""
    return metric_scores(metric, z_mu, z_logvar, p_mu, p_logvar, wq, wk,
                         sigma=sigma)
