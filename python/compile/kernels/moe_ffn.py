"""L1 Pallas kernel: grouped (capacity-binned) SwiGLU expert FFN.

Tokens are pre-binned by the L2 dispatch (`compile.moe`) into a dense
`[E, C, d]` tensor, so the expert compute is a *regular* batched matmul —
the shape the TPU MXU wants. The kernel body operates on an
`[Eb, Cb, d]` block; the grid streams blocks HBM->VMEM via BlockSpec
(the Pallas analogue of the paper's GPU threadblock scheduling — see
DESIGN.md §Hardware-Adaptation).

Block-shape policy (measured, see EXPERIMENTS.md §Perf):
  * real TPU: e_block=1, c_block~128-256 so one expert tile fits VMEM
    and the MXU sees [Cb, d] @ [d, f] matmuls back-to-back.
  * CPU interpret=True (this testbed): every grid iteration costs a
    `lax.while_loop` step with full dynamic-slice copies — measured
    ~2 ms/iteration, i.e. 600x the math it wraps at tiny shapes. CPU
    artifacts therefore lower with e_block=E, c_block=C (ONE grid
    step); the kernel body is identical, only the schedule changes.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO so the same graph runs
under the Rust PJRT client. TPU perf is estimated analytically in
DESIGN.md (VMEM footprint / MXU utilization), never from interpret-mode
wallclock.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _swiglu_kernel(x_ref, w1_ref, w3_ref, w2_ref, o_ref):
    """One (expert-block, capacity-tile) grid step.

    Block shapes: x [Eb, Cb, d], w1/w3 [Eb, d, f], w2 [Eb, f, d],
    o [Eb, Cb, d]. einsum over the expert-block dim keeps the body
    identical for Eb=1 (TPU tiling) and Eb=E (CPU fused lowering).
    """
    x = x_ref[...]
    gate = jnp.einsum("ecd,edf->ecf", x, w1_ref[...])   # MXU matmul 1
    up = jnp.einsum("ecd,edf->ecf", x, w3_ref[...])     # MXU matmul 2
    act = jax.nn.silu(gate) * up                        # VPU elementwise
    o_ref[...] = jnp.einsum("ecf,efd->ecd", act, w2_ref[...])  # matmul 3


def _pick_c_block(capacity: int, c_block: int | None) -> int:
    if c_block is not None:
        assert capacity % c_block == 0, (capacity, c_block)
        return c_block
    # CPU-interpret default: one tile (see module docstring).
    return capacity


def _pick_e_block(e: int, e_block: int | None) -> int:
    if e_block is not None:
        assert e % e_block == 0, (e, e_block)
        return e_block
    return e


@functools.partial(
    jax.jit, static_argnames=("c_block", "e_block", "interpret"))
def moe_ffn(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array,
            *, c_block: int | None = None, e_block: int | None = None,
            interpret: bool = True) -> jax.Array:
    """SwiGLU FFN applied per expert bin.

    Args:
      x:  [E, C, d] dispatched token activations (zero rows for empty
          slots).
      w1: [E, d, f] gate projection.
      w3: [E, d, f] up projection.
      w2: [E, f, d] down projection.
      c_block/e_block: tile sizes (None = whole axis, the CPU default;
          use e_block=1, c_block=128 for the TPU-faithful schedule).
    Returns:
      [E, C, d] expert outputs.
    """
    e, c, d = x.shape
    f = w1.shape[-1]
    assert w1.shape == (e, d, f) and w3.shape == (e, d, f)
    assert w2.shape == (e, f, d)
    cb = _pick_c_block(c, c_block)
    eb = _pick_e_block(e, e_block)

    grid = (e // eb, c // cb)
    return pl.pallas_call(
        _swiglu_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((eb, cb, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((eb, d, f), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((eb, d, f), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((eb, f, d), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((eb, cb, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((e, c, d), x.dtype),
        interpret=interpret,
    )(x, w1, w3, w2)
