"""Differentiable wrappers for the Pallas kernels.

`pallas_call` has no reverse-mode autodiff rule, so the train graph uses
these `jax.custom_vjp` wrappers:

- `moe_ffn_ad`  — forward AND backward are Pallas kernels (the backward
  recomputes gate/up activations per expert tile — rematerialization — so
  the fwd saves only (x, w1, w3, w2), matching what a VMEM-resident TPU
  schedule would keep).
- `router_scores_ad` — forward is the Pallas score kernel; backward is the
  exact VJP of the shared pure-jnp metric math (tiny: N x E x d_z with
  d_z<=256, never a hot spot in the backward pass).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .moe_ffn import _pick_c_block, _pick_e_block, moe_ffn
from .scores import metric_scores, router_scores


# --------------------------------------------------------------------------
# moe_ffn backward kernel
# --------------------------------------------------------------------------

def _swiglu_bwd_kernel(x_ref, w1_ref, w3_ref, w2_ref, dy_ref,
                       dx_ref, dw1_ref, dw3_ref, dw2_ref):
    """Per-(expert-block, C-tile) backward. Recomputes activations
    (rematerialization — the fwd saves only the inputs, matching what a
    VMEM-resident TPU schedule would keep)."""
    x = x_ref[...]          # [Eb, Cb, d]
    w1, w3, w2 = w1_ref[...], w3_ref[...], w2_ref[...]
    dy = dy_ref[...]        # [Eb, Cb, d]

    gate = jnp.einsum("ecd,edf->ecf", x, w1)
    up = jnp.einsum("ecd,edf->ecf", x, w3)
    sg = jax.nn.sigmoid(gate)
    silu = gate * sg
    a = silu * up

    da = jnp.einsum("ecd,efd->ecf", dy, w2)
    dsilu = sg * (1.0 + gate * (1.0 - sg))
    dgate = da * up * dsilu
    dup = da * silu

    dx_ref[...] = (jnp.einsum("ecf,edf->ecd", dgate, w1)
                   + jnp.einsum("ecf,edf->ecd", dup, w3))
    # C-tiles of one expert block accumulate into the same dW block.
    is_first = pl.program_id(1) == 0

    @pl.when(is_first)
    def _init():
        dw1_ref[...] = jnp.zeros_like(dw1_ref[...])
        dw3_ref[...] = jnp.zeros_like(dw3_ref[...])
        dw2_ref[...] = jnp.zeros_like(dw2_ref[...])

    dw1_ref[...] += jnp.einsum("ecd,ecf->edf", x, dgate)
    dw3_ref[...] += jnp.einsum("ecd,ecf->edf", x, dup)
    dw2_ref[...] += jnp.einsum("ecf,ecd->efd", a, dy)


@functools.partial(
    jax.jit, static_argnames=("c_block", "e_block", "interpret"))
def moe_ffn_bwd(x, w1, w3, w2, dy, *, c_block: int | None = None,
                e_block: int | None = None, interpret: bool = True):
    e, c, d = x.shape
    f = w1.shape[-1]
    cb = _pick_c_block(c, c_block)
    eb = _pick_e_block(e, e_block)
    grid = (e // eb, c // cb)
    out_shapes = (
        jax.ShapeDtypeStruct((e, c, d), x.dtype),
        jax.ShapeDtypeStruct((e, d, f), w1.dtype),
        jax.ShapeDtypeStruct((e, d, f), w3.dtype),
        jax.ShapeDtypeStruct((e, f, d), w2.dtype),
    )
    return pl.pallas_call(
        _swiglu_bwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((eb, cb, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((eb, d, f), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((eb, d, f), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((eb, f, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((eb, cb, d), lambda i, j: (i, j, 0)),
        ],
        out_specs=(
            pl.BlockSpec((eb, cb, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((eb, d, f), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((eb, d, f), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((eb, f, d), lambda i, j: (i, 0, 0)),
        ),
        out_shape=out_shapes,
        interpret=interpret,
    )(x, w1, w3, w2, dy)


@jax.custom_vjp
def moe_ffn_ad(x, w1, w3, w2):
    return moe_ffn(x, w1, w3, w2)


def _moe_ffn_fwd(x, w1, w3, w2):
    return moe_ffn(x, w1, w3, w2), (x, w1, w3, w2)


def _moe_ffn_bwd(res, dy):
    return moe_ffn_bwd(*res, dy)


moe_ffn_ad.defvjp(_moe_ffn_fwd, _moe_ffn_bwd)


# --------------------------------------------------------------------------
# router_scores backward (exact VJP of the shared metric math)
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7))
def router_scores_ad(z_mu, z_logvar, p_mu, p_logvar, wq, wk,
                     metric: str, sigma: float):
    return router_scores(z_mu, z_logvar, p_mu, p_logvar, wq, wk,
                         metric=metric, sigma=sigma)


def _scores_fwd(z_mu, z_logvar, p_mu, p_logvar, wq, wk, metric, sigma):
    out = router_scores(z_mu, z_logvar, p_mu, p_logvar, wq, wk,
                        metric=metric, sigma=sigma)
    return out, (z_mu, z_logvar, p_mu, p_logvar, wq, wk)


def _scores_bwd(metric, sigma, res, ds):
    z_mu, z_logvar, p_mu, p_logvar, wq, wk = res

    def pure(zm, zv, pm, pv, q, k):
        return metric_scores(metric, zm, zv, pm, pv, q, k, sigma=sigma)

    _, vjp = jax.vjp(pure, z_mu, z_logvar, p_mu, p_logvar, wq, wk)
    return vjp(ds)


router_scores_ad.defvjp(_scores_fwd, _scores_bwd)
