"""AOT pipeline: lower init/train/eval/router to HLO text + meta.json.

This is the ONLY bridge between python (build time) and rust (runtime).
Per config we emit:

  artifacts/<name>.init.hlo.txt    init(seed:i32[]) -> state...
  artifacts/<name>.train.hlo.txt   train_step(state..., step, lw, tok, tgt)
                                     -> (state'..., metrics, load)
  artifacts/<name>.eval.hlo.txt    eval_step(params..., tok, tgt)
                                     -> (metrics, load)
  artifacts/<name>.router.hlo.txt  router(router_params..., h)
                                     -> (topk_idx, weights, load)
  artifacts/<name>.meta.json       flat buffer contract for the rust side
  artifacts/manifest.json          registry of built artifacts

HLO *text* is the interchange format, NOT serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the published `xla` crate binds) rejects; the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import configs, train
from .configs import Config
from .model import init_params


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _leaf_spec(path, x):
    return {"path": jax.tree_util.keystr(path), "shape": list(x.shape),
            "dtype": str(x.dtype)}


def state_template(cfg: Config):
    """Shapes of (params, m, v) without allocating real weights."""
    return jax.eval_shape(lambda: train.init_state(
        jax.random.PRNGKey(0), cfg))


def build_functions(cfg: Config):
    """Flat-signature wrappers around the pytree train/eval/init fns."""
    params_t, m_t, v_t = state_template(cfg)
    p_leaves, p_def = jax.tree_util.tree_flatten(params_t)
    n_p = len(p_leaves)

    def flatten_state(params, m, v):
        return (jax.tree_util.tree_leaves(params)
                + jax.tree_util.tree_leaves(m)
                + jax.tree_util.tree_leaves(v))

    def unflatten_state(flat):
        p = jax.tree_util.tree_unflatten(p_def, flat[:n_p])
        m = jax.tree_util.tree_unflatten(p_def, flat[n_p:2 * n_p])
        v = jax.tree_util.tree_unflatten(p_def, flat[2 * n_p:3 * n_p])
        return p, m, v

    def init_fn(seed):
        key = jax.random.PRNGKey(seed)
        params, m, v = train.init_state(key, cfg)
        return tuple(flatten_state(params, m, v))

    def train_fn(*args):
        flat = args[:3 * n_p]
        step, lw, tokens, targets = args[3 * n_p:]
        params, m, v = unflatten_state(list(flat))
        params, m, v, metrics, load = train.train_step(
            params, m, v, step, lw, tokens, targets, cfg)
        return tuple(flatten_state(params, m, v)) + (metrics, load)

    def eval_fn(*args):
        flat = args[:n_p]
        tokens, targets = args[n_p:]
        params = jax.tree_util.tree_unflatten(p_def, list(flat))
        metrics, load = train.eval_step(params, tokens, targets, cfg)
        return (metrics, load)

    # Router-only artifact operates on layer-0's router params.
    router_t = params_t["layers"][0]["moe"]["router"]
    r_leaves, r_def = jax.tree_util.tree_flatten(router_t)

    def router_fn(*args):
        flat = args[:len(r_leaves)]
        h = args[len(r_leaves)]
        rp = jax.tree_util.tree_unflatten(r_def, list(flat))
        return train.router_only(rp, h, cfg)

    return {
        "n_params": n_p,
        "params_t": params_t, "router_t": router_t,
        "init_fn": init_fn, "train_fn": train_fn,
        "eval_fn": eval_fn, "router_fn": router_fn,
    }


def lower_config(cfg: Config, out_dir: str, verbose: bool = True) -> dict:
    t0 = time.time()
    fns = build_functions(cfg)
    params_t, router_t = fns["params_t"], fns["router_t"]
    n_p = fns["n_params"]

    b, t = cfg.batch_size, cfg.seq_len
    state_specs = [jax.ShapeDtypeStruct(x.shape, x.dtype)
                   for x in jax.tree_util.tree_leaves(params_t)] * 3
    step_s = jax.ShapeDtypeStruct((), jnp.int32)
    lw_s = jax.ShapeDtypeStruct((len(configs.LOSS_WEIGHTS),), jnp.float32)
    tok_s = jax.ShapeDtypeStruct((b, t), jnp.int32)
    h_s = jax.ShapeDtypeStruct((cfg.tokens_per_batch, cfg.d_model),
                               jnp.float32)
    router_specs = [jax.ShapeDtypeStruct(x.shape, x.dtype)
                    for x in jax.tree_util.tree_leaves(router_t)]

    files = {}

    def emit(kind, fn, specs):
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{cfg.name}.{kind}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        files[kind] = {"file": fname,
                       "sha256": hashlib.sha256(
                           text.encode()).hexdigest()[:16],
                       "bytes": len(text)}
        if verbose:
            print(f"  {fname}: {len(text)/1e6:.2f} MB")

    emit("init", fns["init_fn"], [step_s])
    emit("train", fns["train_fn"],
         state_specs + [step_s, lw_s, tok_s, tok_s])
    emit("eval", fns["eval_fn"], state_specs[:n_p] + [tok_s, tok_s])
    emit("router", fns["router_fn"], router_specs + [h_s])

    # Flat-buffer contract for the rust runtime.
    p_paths = jax.tree_util.tree_flatten_with_path(params_t)[0]
    r_paths = jax.tree_util.tree_flatten_with_path(router_t)[0]
    meta = {
        "name": cfg.name,
        "config": cfg.to_json(),
        "files": files,
        "n_params": n_p,
        "n_state": 3 * n_p,
        "params": [_leaf_spec(p, x) for p, x in p_paths],
        "router_params": [_leaf_spec(p, x) for p, x in r_paths],
        "loss_weights": configs.LOSS_WEIGHTS,
        "default_loss_weights": cfg.default_loss_weights(),
        "metric_names": train.METRIC_NAMES,
        "eval_metric_names": ["loss", "drop_frac"],
        "load_shape": [cfg.n_layers, cfg.n_experts],
        "batch_shape": [b, t],
        "router_in_shape": list(h_s.shape),
        "topk_shape": [cfg.tokens_per_batch, cfg.top_k],
        "param_count": int(sum(
            int(jnp.prod(jnp.array(x.shape)))
            for x in jax.tree_util.tree_leaves(params_t))),
        "train_inputs": (["state"] * (3 * n_p)
                         + ["step", "loss_weights", "tokens", "targets"]),
    }
    with open(os.path.join(out_dir, f"{cfg.name}.meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    if verbose:
        print(f"  [{cfg.name}] {meta['param_count']/1e6:.2f}M params, "
              f"{time.time()-t0:.1f}s")
    return meta


def write_goldens(out_dir: str):
    """Input/output pairs for the rust<->jax router parity tests."""
    gdir = os.path.join(out_dir, "goldens")
    os.makedirs(gdir, exist_ok=True)

    # Load-balance metric goldens (gini/min-max/entropy/cv cross-check).
    from . import metrics as M
    rng = jax.random.PRNGKey(123)
    cases = []
    for i, load in enumerate([
            [1.0] * 8,
            [0.0] * 7 + [1.0],
            [1, 2, 3, 4, 5, 6, 7, 8],
            list(jnp.abs(jax.random.normal(rng, (32,))).tolist()),
            [0.0, 0.0, 5.0, 5.0],
    ]):
        cases.append({"load": [float(x) for x in load],
                      "gini": M.gini(load),
                      "min_max": M.min_max_ratio(load),
                      "entropy_frac": M.entropy_frac(load),
                      "cv": M.cv(load)})
    with open(os.path.join(gdir, "metrics.json"), "w") as f:
        json.dump(cases, f)
    print("  golden metrics written")
    for router, metric in (("vanilla", "dot"), ("lpr", "cosine"),
                           ("lpr", "gaussian"), ("deepseek", "dot")):
        cfg = Config(name=f"golden-{router}-{metric}", router=router,
                     metric=metric, d_model=32, n_experts=8, top_k=2,
                     latent_dim=8, n_layers=1, seq_len=8, batch_size=2,
                     vocab=64, n_heads=2, n_kv_heads=1, head_dim=16,
                     moe_d_ff=16, variational=False)
        key = jax.random.PRNGKey(7)
        params = init_params(key, cfg)
        rp = params["layers"][0]["moe"]["router"]
        h = jax.random.normal(jax.random.fold_in(key, 1),
                              (16, cfg.d_model), jnp.float32)
        topk, w, load = train.router_only(rp, h, cfg)
        flat = {
            "config": cfg.to_json(),
            "router_params": {
                jax.tree_util.keystr(p): jnp.asarray(x).tolist()
                for p, x in jax.tree_util.tree_flatten_with_path(rp)[0]},
            "h": h.tolist(),
            "topk_idx": topk.tolist(),
            "weights": w.tolist(),
            "load": load.tolist(),
        }
        path = os.path.join(gdir, f"{router}-{metric}.json")
        with open(path, "w") as f:
            json.dump(flat, f)
        print(f"  golden {router}-{metric} written")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--presets", default="all",
                    help="comma-separated preset names, or 'all'")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args(argv)

    if args.list:
        for name in sorted(configs.REGISTRY):
            print(name)
        return

    names = (sorted(configs.REGISTRY) if args.presets == "all"
             else args.presets.split(","))
    os.makedirs(args.out, exist_ok=True)

    manifest = {"artifacts": {}}
    mpath = os.path.join(args.out, "manifest.json")
    if os.path.exists(mpath):
        with open(mpath) as f:
            manifest = json.load(f)

    for i, name in enumerate(names):
        cfg = configs.get(name)
        print(f"[{i+1}/{len(names)}] lowering {name} ...")
        meta = lower_config(cfg, args.out)
        manifest["artifacts"][name] = meta["files"]
        with open(mpath, "w") as f:
            json.dump(manifest, f, indent=1)

    write_goldens(args.out)
    print(f"manifest: {mpath} ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
