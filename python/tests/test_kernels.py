"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

hypothesis sweeps shapes/metrics; assert_allclose against ref.py is the
core correctness signal for everything the AOT artifacts contain.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ALL_METRICS, moe_ffn, router_scores
from compile.kernels.ref import moe_ffn_ref, router_scores_ref
from compile.kernels.vjp import moe_ffn_ad, moe_ffn_bwd, router_scores_ad


def _rand(key, *shape, scale=1.0):
    return jax.random.normal(key, shape, jnp.float32) * scale


def keys(n, seed=0):
    return jax.random.split(jax.random.PRNGKey(seed), n)


# ---------------------------------------------------------------- moe_ffn

@given(e=st.sampled_from([1, 2, 4, 8]),
       c=st.sampled_from([8, 32, 96, 160]),
       d=st.sampled_from([8, 16, 64]),
       f=st.sampled_from([8, 24, 64]),
       seed=st.integers(0, 2**16))
def test_moe_ffn_matches_ref(e, c, d, f, seed):
    k = keys(4, seed)
    x = _rand(k[0], e, c, d)
    w1 = _rand(k[1], e, d, f, scale=0.2)
    w3 = _rand(k[2], e, d, f, scale=0.2)
    w2 = _rand(k[3], e, f, d, scale=0.2)
    out = moe_ffn(x, w1, w3, w2)
    ref = moe_ffn_ref(x, w1, w3, w2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


def test_moe_ffn_zero_rows_stay_zero():
    # Empty capacity slots (zero rows) must produce zero output: SwiGLU(0)=0.
    k = keys(3)
    e, c, d, f = 2, 16, 8, 12
    x = jnp.zeros((e, c, d))
    out = moe_ffn(x, _rand(k[0], e, d, f), _rand(k[1], e, d, f),
                  _rand(k[2], e, f, d))
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-7)


def test_moe_ffn_c_block_tiling_invariance():
    k = keys(4)
    e, c, d, f = 2, 128, 16, 16
    args = (_rand(k[0], e, c, d), _rand(k[1], e, d, f, scale=0.2),
            _rand(k[2], e, d, f, scale=0.2), _rand(k[3], e, f, d, scale=0.2))
    full = moe_ffn(*args, c_block=128)
    tiled = moe_ffn(*args, c_block=32)
    np.testing.assert_allclose(np.asarray(full), np.asarray(tiled),
                               rtol=1e-5, atol=1e-6)


def test_moe_ffn_bwd_matches_autodiff_of_ref():
    k = keys(5)
    e, c, d, f = 2, 32, 8, 12
    x = _rand(k[0], e, c, d)
    w1 = _rand(k[1], e, d, f, scale=0.2)
    w3 = _rand(k[2], e, d, f, scale=0.2)
    w2 = _rand(k[3], e, f, d, scale=0.2)
    dy = _rand(k[4], e, c, d)

    def ref_loss(x, w1, w3, w2):
        return jnp.sum(moe_ffn_ref(x, w1, w3, w2) * dy)

    want = jax.grad(ref_loss, argnums=(0, 1, 2, 3))(x, w1, w3, w2)
    got = moe_ffn_bwd(x, w1, w3, w2, dy)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-4, atol=1e-5)


def test_moe_ffn_ad_custom_vjp_end_to_end():
    k = keys(4)
    e, c, d, f = 2, 16, 8, 8
    x = _rand(k[0], e, c, d)
    w1 = _rand(k[1], e, d, f, scale=0.2)
    w3 = _rand(k[2], e, d, f, scale=0.2)
    w2 = _rand(k[3], e, f, d, scale=0.2)

    g_kernel = jax.grad(lambda *a: jnp.sum(moe_ffn_ad(*a) ** 2),
                        argnums=(0, 1, 2, 3))(x, w1, w3, w2)
    g_ref = jax.grad(lambda *a: jnp.sum(moe_ffn_ref(*a) ** 2),
                     argnums=(0, 1, 2, 3))(x, w1, w3, w2)
    for a, b in zip(g_kernel, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


# ------------------------------------------------------------ router_scores

@given(metric=st.sampled_from(ALL_METRICS),
       n=st.sampled_from([8, 48, 128]),
       e=st.sampled_from([4, 8, 64]),
       dz=st.sampled_from([4, 16]),
       seed=st.integers(0, 2**16))
def test_scores_match_ref(metric, n, e, dz, seed):
    k = keys(6, seed)
    zm = _rand(k[0], n, dz)
    zv = _rand(k[1], n, dz, scale=0.3)
    pm = _rand(k[2], e, dz)
    pv = _rand(k[3], e, dz, scale=0.3)
    h, dh = 4, max(1, dz // 4)
    wq = _rand(k[4], h, dz, dh, scale=0.5)
    wk = _rand(k[5], h, dz, dh, scale=0.5)
    out = router_scores(zm, zv, pm, pv, wq, wk, metric=metric)
    ref = router_scores_ref(zm, zv, pm, pv, wq, wk, metric=metric)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=5e-5, atol=5e-5)


@pytest.mark.parametrize("metric", ALL_METRICS)
def test_scores_identity_is_best_match(metric):
    """A token latent equal to prototype i must score highest for i."""
    e, dz = 6, 8
    pm = _rand(keys(1)[0], e, dz)
    pm = pm / jnp.linalg.norm(pm, axis=-1, keepdims=True)
    pv = jnp.full((e, dz), -2.0)
    zm, zv = pm, pv
    if metric == "xattn":
        pytest.skip("xattn has learned projections; no identity property")
    s = router_scores_ref(zm, zv, pm, pv, metric=metric)
    np.testing.assert_array_equal(np.argmax(np.asarray(s), axis=-1),
                                  np.arange(e))


@pytest.mark.parametrize("metric", ["wasserstein", "kl", "js", "hellinger"])
def test_distributional_self_distance_zero(metric):
    n, dz = 5, 8
    k = keys(2)
    mu = _rand(k[0], n, dz)
    lv = _rand(k[1], n, dz, scale=0.2)
    s = router_scores_ref(mu, lv, mu, lv, metric=metric)
    diag = np.diag(np.asarray(s))
    np.testing.assert_allclose(diag, 0.0, atol=1e-4)


def test_hellinger_bounded():
    k = keys(4)
    s = router_scores_ref(_rand(k[0], 16, 8), _rand(k[1], 16, 8),
                          _rand(k[2], 4, 8) * 3, _rand(k[3], 4, 8),
                          metric="hellinger")
    v = -np.asarray(s)  # squared Hellinger distance
    assert (v >= -1e-5).all() and (v <= 1.0 + 1e-5).all()


def test_gaussian_kernel_in_unit_interval():
    k = keys(2)
    s = router_scores_ref(_rand(k[0], 32, 8), jnp.zeros((32, 8)),
                          _rand(k[1], 8, 8), jnp.zeros((8, 8)),
                          metric="gaussian")
    v = np.asarray(s)
    assert (v > 0).all() and (v <= 1.0 + 1e-6).all()


@pytest.mark.parametrize("metric", ["cosine", "kl", "wasserstein", "xattn"])
def test_scores_ad_grads_match_pure(metric):
    k = keys(6)
    n, e, dz = 16, 4, 8
    args = [_rand(k[0], n, dz), _rand(k[1], n, dz, scale=0.2),
            _rand(k[2], e, dz), _rand(k[3], e, dz, scale=0.2),
            _rand(k[4], 4, dz, 2, scale=0.5), _rand(k[5], 4, dz, 2,
                                                    scale=0.5)]

    def f_ad(*a):
        return jnp.sum(router_scores_ad(*a, metric, 1.0) ** 2)

    def f_ref(*a):
        return jnp.sum(router_scores_ref(*a, metric=metric) ** 2)

    g_ad = jax.grad(f_ad, argnums=(0, 2))(*args)
    g_ref = jax.grad(f_ref, argnums=(0, 2))(*args)
    for a, b in zip(g_ad, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
