"""Load-balance metric definitions (paper eq.25-26)."""
import numpy as np
import pytest
from hypothesis import given
from hypothesis import strategies as st

from compile.metrics import cv, entropy_frac, gini, min_max_ratio


def test_gini_uniform_is_zero():
    assert gini([5.0] * 16) == pytest.approx(0.0, abs=1e-12)


def test_gini_single_expert_takes_all():
    # one of n experts holds all load -> gini = (n-1)/n
    n = 8
    load = [0.0] * (n - 1) + [10.0]
    assert gini(load) == pytest.approx((n - 1) / n)


def test_gini_known_value():
    # loads 1..4: gini = sum((2i-n-1) x_i) / (n * sum) = 10/40 = 0.25
    assert gini([1, 2, 3, 4]) == pytest.approx(0.25)


def test_gini_scale_invariant():
    a = [1, 5, 2, 9, 3]
    assert gini(a) == pytest.approx(gini([x * 37.5 for x in a]))


def test_gini_permutation_invariant():
    a = [1, 5, 2, 9, 3]
    assert gini(a) == pytest.approx(gini(list(reversed(a))))


@given(st.lists(st.floats(0.0, 1e6), min_size=2, max_size=64))
def test_gini_bounds(xs):
    g = gini(xs)
    assert -1e-9 <= g <= 1.0


def test_min_max_uniform():
    assert min_max_ratio([3.0] * 4) == pytest.approx(1.0, rel=1e-6)


def test_min_max_starved_expert():
    assert min_max_ratio([0.0, 10.0]) == pytest.approx(0.0, abs=1e-9)


@given(st.lists(st.floats(0.001, 1e3), min_size=2, max_size=64))
def test_min_max_bounds(xs):
    r = min_max_ratio(xs)
    assert 0.0 <= r <= 1.0 + 1e-9


def test_entropy_uniform_is_one():
    assert entropy_frac([2.0] * 32) == pytest.approx(1.0, rel=1e-9)


def test_cv_uniform_is_zero():
    assert cv([7.0] * 5) == pytest.approx(0.0, abs=1e-9)


def test_imbalance_orders_consistently():
    """All four metrics must order a balanced load before a skewed one."""
    balanced = [10.0] * 8
    skewed = [1.0] * 7 + [93.0]
    assert gini(balanced) < gini(skewed)
    assert min_max_ratio(balanced) > min_max_ratio(skewed)
    assert entropy_frac(balanced) > entropy_frac(skewed)
    assert cv(balanced) < cv(skewed)
