"""Router contract tests: vanilla / deepseek / LPR."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given
from hypothesis import strategies as st

from compile.configs import Config, METRICS
from compile.routers import (deepseek_fwd, diversity_loss, encode,
                             init_router, lpr_fwd, router_fwd)


def tiny_cfg(**kw):
    base = dict(name="t", d_model=32, n_experts=8, top_k=2, latent_dim=8,
                n_layers=1, seq_len=8, batch_size=2, vocab=64, n_heads=2,
                n_kv_heads=1, head_dim=16, moe_d_ff=16)
    base.update(kw)
    return Config(**base)


def run_router(cfg, n=32, seed=0, train=True):
    k = jax.random.PRNGKey(seed)
    p = init_router(k, cfg)
    h = jax.random.normal(jax.random.fold_in(k, 1), (n, cfg.d_model))
    return router_fwd(p, h, cfg, rng=jax.random.fold_in(k, 2), train=train)


@pytest.mark.parametrize("router", ["vanilla", "deepseek", "lpr"])
def test_contract_shapes_and_ranges(router):
    cfg = tiny_cfg(router=router)
    out = run_router(cfg, n=32)
    n, e, k = 32, cfg.n_experts, cfg.top_k
    assert out.topk_idx.shape == (n, k)
    assert out.combine_w.shape == (n, k)
    assert out.scores.shape == (n, e)
    assert out.load.shape == (e,)
    idx = np.asarray(out.topk_idx)
    assert idx.min() >= 0 and idx.max() < e
    # top-k must be distinct experts per token
    for row in idx:
        assert len(set(row.tolist())) == k
    w = np.asarray(out.combine_w)
    assert (w >= -1e-6).all()
    np.testing.assert_allclose(w.sum(-1), 1.0, rtol=1e-5)
    assert float(jnp.sum(out.load)) == pytest.approx(n * k)
    for val in out.losses.values():
        assert np.isfinite(float(val))


@given(router=st.sampled_from(["vanilla", "deepseek", "lpr"]),
       seed=st.integers(0, 1000), n=st.sampled_from([16, 64]))
def test_load_conservation(router, seed, n):
    cfg = tiny_cfg(router=router)
    out = run_router(cfg, n=n, seed=seed)
    assert float(jnp.sum(out.load)) == pytest.approx(n * cfg.top_k)


@pytest.mark.parametrize("metric", [m for m in METRICS if m != "dot"])
def test_lpr_all_metrics_run(metric):
    cfg = tiny_cfg(router="lpr", metric=metric)
    out = run_router(cfg)
    assert np.isfinite(np.asarray(out.scores)).all()
    assert float(out.losses["kl"]) >= 0.0
    assert float(out.losses["div"]) >= 0.0
    assert float(out.losses["align"]) >= 0.0


def test_hypersphere_init_unit_norm():
    cfg = tiny_cfg(router="lpr", hypersphere_init=True)
    p = init_router(jax.random.PRNGKey(0), cfg)
    norms = np.linalg.norm(np.asarray(p["proto_mu"]), axis=-1)
    np.testing.assert_allclose(norms, 1.0, rtol=1e-5)


def test_no_init_is_not_unit_norm():
    cfg = tiny_cfg(router="lpr", hypersphere_init=False)
    p = init_router(jax.random.PRNGKey(0), cfg)
    norms = np.linalg.norm(np.asarray(p["proto_mu"]), axis=-1)
    assert np.abs(norms - 1.0).max() > 0.05


def test_encoder_logvar_clipped():
    cfg = tiny_cfg(router="lpr")
    p = init_router(jax.random.PRNGKey(0), cfg)
    h = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model)) * 100.0
    _, lv = encode(p, h)
    v = np.asarray(lv)
    assert v.min() >= -8.0 - 1e-6 and v.max() <= 4.0 + 1e-6


def test_variational_eval_is_deterministic():
    cfg = tiny_cfg(router="lpr", variational=True)
    k = jax.random.PRNGKey(0)
    p = init_router(k, cfg)
    h = jax.random.normal(jax.random.fold_in(k, 1), (32, cfg.d_model))
    a = lpr_fwd(p, h, cfg, rng=None, train=False)
    b = lpr_fwd(p, h, cfg, rng=None, train=False)
    np.testing.assert_array_equal(np.asarray(a.topk_idx),
                                  np.asarray(b.topk_idx))
    np.testing.assert_allclose(np.asarray(a.scores), np.asarray(b.scores))


def test_variational_train_uses_noise():
    cfg = tiny_cfg(router="lpr", variational=True)
    k = jax.random.PRNGKey(0)
    p = init_router(k, cfg)
    # widen sigma so the reparam noise is visible in scores
    p["b_lv"] = jnp.zeros_like(p["b_lv"])
    h = jax.random.normal(jax.random.fold_in(k, 1), (32, cfg.d_model))
    a = lpr_fwd(p, h, cfg, rng=jax.random.PRNGKey(1), train=True)
    b = lpr_fwd(p, h, cfg, rng=jax.random.PRNGKey(2), train=True)
    assert np.abs(np.asarray(a.scores) - np.asarray(b.scores)).max() > 1e-6


@pytest.mark.parametrize("kind", ["orthogonal", "cosine", "euclidean"])
def test_diversity_loss_prefers_separated_prototypes(kind):
    e, dz = 8, 8
    sep = jnp.eye(e, dz) * 2.0            # orthogonal, well separated
    collapsed = jnp.ones((e, dz))         # all identical
    l_sep = float(diversity_loss(kind, sep))
    l_col = float(diversity_loss(kind, collapsed))
    assert l_sep < l_col, (kind, l_sep, l_col)
    assert l_sep >= 0.0


def test_diversity_none_is_zero():
    assert float(diversity_loss("none", jnp.ones((4, 4)))) == 0.0


def test_deepseek_bias_influences_selection_only():
    cfg = tiny_cfg(router="deepseek")
    k = jax.random.PRNGKey(0)
    p = init_router(k, cfg)
    h = jax.random.normal(jax.random.fold_in(k, 1), (64, cfg.d_model))
    base = deepseek_fwd(p, h, cfg)
    # A huge bias on expert 0 must force it into every top-k set ...
    p2 = dict(p, bias=p["bias"].at[0].add(100.0))
    out = deepseek_fwd(p2, h, cfg)
    assert (np.asarray(out.topk_idx) == 0).any(axis=-1).all()
    # ... but combine weights still come from the raw (bias-free)
    # affinities: weights for a token's unchanged expert set are equal.
    del base


def test_deepseek_bias_delta_points_toward_balance():
    cfg = tiny_cfg(router="deepseek")
    out = run_router(cfg, n=64)
    delta = np.asarray(out.updates["bias_delta"])
    load = np.asarray(out.load)
    # Overloaded experts get negative delta, starved experts positive.
    assert (delta[load > load.mean()] <= 0).all()
    assert (delta[load < load.mean()] >= 0).all()


def test_lpr_ema_target_is_assigned_token_mean():
    cfg = tiny_cfg(router="lpr", variational=False)
    k = jax.random.PRNGKey(3)
    p = init_router(k, cfg)
    h = jax.random.normal(jax.random.fold_in(k, 1), (48, cfg.d_model))
    out = lpr_fwd(p, h, cfg, rng=None, train=True)
    mu, _ = encode(p, h)
    z = np.asarray(mu)
    idx = np.asarray(out.topk_idx)
    tgt = np.asarray(out.updates["ema_target"])
    for e in range(cfg.n_experts):
        mask = (idx == e).any(axis=-1)
        if mask.sum() == 0:
            np.testing.assert_allclose(tgt[e], np.asarray(p["proto_mu"])[e],
                                       rtol=1e-5)
        else:
            np.testing.assert_allclose(tgt[e], z[mask].mean(0), rtol=1e-4,
                                       atol=1e-5)


def test_unit_ball_constraint_caps_prototype_norm():
    cfg = tiny_cfg(router="lpr", unit_ball=True, variational=False,
                   metric="gaussian")
    k = jax.random.PRNGKey(0)
    p = init_router(k, cfg)
    p["proto_mu"] = p["proto_mu"] * 100.0  # blow up the raw parameter
    h = jax.random.normal(jax.random.fold_in(k, 1), (16, cfg.d_model))
    out = lpr_fwd(p, h, cfg, rng=None, train=False)
    # gaussian scores are exp(-d^2/2); with unit-ball projection distances
    # stay small, so scores stay far from 0.
    assert np.asarray(out.scores).max() > 1e-3
