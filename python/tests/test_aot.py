"""AOT pipeline: lowering determinism, meta contract, goldens."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, configs, train
from compile.configs import Config
from compile.model import init_params


@pytest.fixture(scope="module")
def art(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    aot.main(["--out", out, "--presets", "quickstart"])
    return out


def test_artifact_files_exist(art):
    for kind in ("init", "train", "eval", "router"):
        p = os.path.join(art, f"quickstart.{kind}.hlo.txt")
        assert os.path.exists(p) and os.path.getsize(p) > 1000, p
    assert os.path.exists(os.path.join(art, "quickstart.meta.json"))
    assert os.path.exists(os.path.join(art, "manifest.json"))


def test_hlo_is_text_not_proto(art):
    head = open(os.path.join(art, "quickstart.train.hlo.txt")).read(200)
    assert "HloModule" in head  # textual HLO, parseable by xla 0.5.1


def test_meta_contract(art):
    meta = json.load(open(os.path.join(art, "quickstart.meta.json")))
    cfg = configs.get("quickstart")
    assert meta["n_state"] == 3 * meta["n_params"]
    assert meta["load_shape"] == [cfg.n_layers, cfg.n_experts]
    assert meta["batch_shape"] == [cfg.batch_size, cfg.seq_len]
    assert len(meta["params"]) == meta["n_params"]
    assert meta["metric_names"] == train.METRIC_NAMES
    # declared param count equals the sum over leaf shapes
    total = sum(int(np.prod(p["shape"])) for p in meta["params"])
    assert total == meta["param_count"]
    # train input list: state then step/lw/tokens/targets
    ti = meta["train_inputs"]
    assert ti[-4:] == ["step", "loss_weights", "tokens", "targets"]
    assert len(ti) == meta["n_state"] + 4


def test_flat_roundtrip_matches_pytree():
    """The flat-signature wrappers must equal the pytree train step."""
    cfg = Config(name="rt", d_model=32, n_experts=8, top_k=2, latent_dim=8,
                 n_layers=1, seq_len=8, batch_size=2, vocab=64, n_heads=2,
                 n_kv_heads=1, head_dim=16, moe_d_ff=16, total_steps=10)
    fns = aot.build_functions(cfg)
    key = jax.random.PRNGKey(0)
    params, m, v = train.init_state(key, cfg)
    lw = jnp.array(cfg.default_loss_weights(), jnp.float32)
    tok = jax.random.randint(key, (2, 8), 0, 64)
    tgt = jnp.roll(tok, -1, 1)

    want = train.train_step(params, m, v, jnp.int32(0), lw, tok, tgt, cfg)
    flat_in = (jax.tree_util.tree_leaves(params)
               + jax.tree_util.tree_leaves(m)
               + jax.tree_util.tree_leaves(v))
    got = fns["train_fn"](*flat_in, jnp.int32(0), lw, tok, tgt)
    np_want = jax.tree_util.tree_leaves(want)
    assert len(got) == len(np_want)
    for a, b in zip(got, np_want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_init_fn_deterministic():
    cfg = configs.get("quickstart")
    fns = aot.build_functions(cfg)
    a = fns["init_fn"](jnp.int32(42))
    b = fns["init_fn"](jnp.int32(42))
    c = fns["init_fn"](jnp.int32(7))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert any(np.abs(np.asarray(x) - np.asarray(y)).max() > 0
               for x, y in zip(a, c))


def test_goldens_reproduce(art):
    gdir = os.path.join(art, "goldens")
    for fname in os.listdir(gdir):
        if fname == "metrics.json":
            continue
        g = json.load(open(os.path.join(gdir, fname)))
        cfg = Config(**g["config"])
        key = jax.random.PRNGKey(7)
        params = init_params(key, cfg)
        rp = params["layers"][0]["moe"]["router"]
        h = jnp.asarray(g["h"], jnp.float32)
        topk, w, load = train.router_only(rp, h, cfg)
        np.testing.assert_array_equal(np.asarray(topk),
                                      np.asarray(g["topk_idx"]))
        np.testing.assert_allclose(np.asarray(w), np.asarray(g["weights"]),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(load),
                                   np.asarray(g["load"]), rtol=1e-6)


def test_registry_presets_cover_paper_tables():
    names = set(configs.REGISTRY)
    for required in ("t1-qwen3", "t1-qwen3-lpr", "t1-qwen3-lpr-noinit",
                     "t1-deepseek", "t1-deepseek-lpr", "t1-mixtral",
                     "t1-mixtral-lpr", "ab-base", "fig1-vanilla",
                     "fig1-lpr", "e2e-lm", "quickstart"):
        assert required in names, required
    assert sum(1 for n in names if n.startswith("t3-dim")) == 7
    assert sum(1 for n in names if n.startswith("t5-")) == 5
    assert sum(1 for n in names if n.startswith("t6-div")) == 3
    assert sum(1 for n in names if n.startswith("t7-")) == 8


def test_all_registry_configs_valid():
    for name, cfg in configs.REGISTRY.items():
        assert cfg.capacity >= 4, name
        assert cfg.tokens_per_batch % 8 == 0, name
