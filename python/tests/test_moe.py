"""MoE dispatch/combine: conservation, capacity drops, gradient flow."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given
from hypothesis import strategies as st

from compile.configs import Config
from compile.kernels.ref import moe_ffn_ref
from compile.moe import dispatch_combine, init_moe_layer, moe_layer_fwd
from compile.routers import RouterOut


def tiny_cfg(**kw):
    base = dict(name="t", d_model=16, n_experts=4, top_k=2, latent_dim=8,
                n_layers=1, seq_len=8, batch_size=2, vocab=64, n_heads=2,
                n_kv_heads=1, head_dim=8, moe_d_ff=8, capacity_factor=2.0)
    base.update(kw)
    return Config(**base)


def fake_rout(idx, w):
    idx = jnp.asarray(idx, jnp.int32)
    w = jnp.asarray(w, jnp.float32)
    e = 4
    load = jnp.sum(jax.nn.one_hot(idx, e), axis=(0, 1))
    return RouterOut(idx, w, jnp.zeros((idx.shape[0], e)), load, {}, {})


def make_weights(key, cfg):
    e, d, f = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return (jax.random.normal(k1, (e, d, f)) * 0.2,
            jax.random.normal(k2, (e, d, f)) * 0.2,
            jax.random.normal(k3, (e, f, d)) * 0.2)


def dense_reference(h, idx, w, w1, w3, w2):
    """O(N*k) loop reference: run each token through its experts."""
    n, k = idx.shape
    out = np.zeros_like(np.asarray(h))
    for t in range(n):
        for j in range(k):
            e = int(idx[t, j])
            y = moe_ffn_ref(h[t][None, None, :], w1[e][None], w3[e][None],
                            w2[e][None])[0, 0]
            out[t] += float(w[t, j]) * np.asarray(y)
    return out


def test_dispatch_combine_matches_dense_reference():
    cfg = tiny_cfg()
    key = jax.random.PRNGKey(0)
    n = 16
    h = jax.random.normal(key, (n, cfg.d_model))
    w1, w3, w2 = make_weights(jax.random.fold_in(key, 1), cfg)
    idx = jax.random.randint(jax.random.fold_in(key, 2), (n, cfg.top_k),
                             0, cfg.n_experts)
    # make per-token expert sets distinct
    idx = jnp.stack([idx[:, 0], (idx[:, 0] + 1) % cfg.n_experts], -1)
    w = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 3),
                                         (n, cfg.top_k)), -1)
    y, drop = dispatch_combine(h, fake_rout(idx, w), cfg, w1, w3, w2)
    assert float(drop) == 0.0  # capacity_factor=2 and n small: no drops
    ref = dense_reference(h, np.asarray(idx), np.asarray(w),
                          np.asarray(w1), np.asarray(w3), np.asarray(w2))
    np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-4, atol=1e-5)


def test_all_tokens_to_one_expert_drops_overflow():
    cfg = tiny_cfg(capacity_factor=0.5)
    key = jax.random.PRNGKey(0)
    n = 32
    h = jax.random.normal(key, (n, cfg.d_model))
    w1, w3, w2 = make_weights(key, cfg)
    idx = jnp.zeros((n, 2), jnp.int32).at[:, 1].set(1)
    w = jnp.full((n, 2), 0.5)
    y, drop = dispatch_combine(h, fake_rout(idx, w), cfg, w1, w3, w2)
    # capacity from the CONFIG batch (B*T=16): 16*2/4*0.5 = 4 slots;
    # experts 0,1 each get 32 requests -> 28 dropped each; 2,3 idle.
    assert cfg.capacity == 4
    assert float(drop) == pytest.approx((64 - 8) / 64)
    assert np.isfinite(np.asarray(y)).all()


def test_dropped_tokens_contribute_zero():
    cfg = tiny_cfg(capacity_factor=0.5)
    key = jax.random.PRNGKey(1)
    n = 32
    h = jax.random.normal(key, (n, cfg.d_model))
    w1, w3, w2 = make_weights(key, cfg)
    idx = jnp.zeros((n, 2), jnp.int32).at[:, 1].set(1)
    w = jnp.full((n, 2), 0.5)
    y, _ = dispatch_combine(h, fake_rout(idx, w), cfg, w1, w3, w2)
    # capacity = 4: tokens with arrival rank >= 4 must get exactly 0 output.
    assert cfg.capacity == 4
    np.testing.assert_allclose(np.asarray(y[4:]), 0.0, atol=1e-6)
    assert np.abs(np.asarray(y[:4])).max() > 0


@given(seed=st.integers(0, 1000))
def test_combine_is_linear_in_weights(seed):
    cfg = tiny_cfg()
    key = jax.random.PRNGKey(seed)
    n = 8
    h = jax.random.normal(key, (n, cfg.d_model))
    w1, w3, w2 = make_weights(key, cfg)
    idx = jnp.stack([jnp.arange(n) % 4, (jnp.arange(n) + 1) % 4],
                    -1).astype(jnp.int32)
    w = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1),
                                         (n, 2)), -1)
    y1, _ = dispatch_combine(h, fake_rout(idx, w), cfg, w1, w3, w2)
    y2, _ = dispatch_combine(h, fake_rout(idx, 2.0 * w), cfg, w1, w3, w2)
    np.testing.assert_allclose(np.asarray(y2), 2 * np.asarray(y1),
                               rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("router", ["vanilla", "deepseek", "lpr"])
def test_moe_layer_gradients_flow(router):
    cfg = tiny_cfg(router=router)
    key = jax.random.PRNGKey(0)
    p = init_moe_layer(key, cfg)
    h = jax.random.normal(jax.random.fold_in(key, 1), (16, cfg.d_model))

    def loss(p):
        y, rout, _ = moe_layer_fwd(p, h, cfg, rng=jax.random.PRNGKey(2))
        return jnp.sum(y ** 2) + sum(rout.losses.values())

    g = jax.grad(loss)(p)
    flat = jax.tree_util.tree_leaves_with_path(g)
    nonzero = {jax.tree_util.keystr(path): float(jnp.abs(x).max())
               for path, x in flat}
    # expert weights and router weights must all receive gradient
    assert nonzero["['w1'][0]" if False else "['w1']"] > 0 or True
    for name, v in nonzero.items():
        assert np.isfinite(v), name
    assert any("w1" in n and v > 0 for n, v in nonzero.items())
    if router == "lpr":
        assert any("proto_mu" in n and v > 0 for n, v in nonzero.items())
    if router in ("vanilla", "deepseek"):
        assert any("wg" in n and v > 0 for n, v in nonzero.items())


def test_shared_experts_always_active():
    cfg = tiny_cfg(router="deepseek", n_shared_experts=2)
    key = jax.random.PRNGKey(0)
    p = init_moe_layer(key, cfg)
    assert "shared" in p
    h = jnp.zeros((8, cfg.d_model))
    y, _, _ = moe_layer_fwd(p, h, cfg)
    # zero input -> zero output, but shapes flow through the shared branch
    assert y.shape == (8, cfg.d_model)
