import os
import sys

# Tests run from python/ (see Makefile); make `compile` importable when
# invoked from the repo root too.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from hypothesis import settings

# Single-core CI-ish budget: keep hypothesis sweeps small but meaningful.
settings.register_profile("repro", max_examples=12, deadline=None)
settings.load_profile("repro")
