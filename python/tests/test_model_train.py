"""Model fwd + train step: shapes for all archs, learning, schedule."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import train as T
from compile.configs import Config
from compile.model import forward, init_params, total_loss


def tiny_cfg(**kw):
    base = dict(name="t", d_model=32, n_experts=8, top_k=2, latent_dim=8,
                n_layers=2, seq_len=16, batch_size=2, vocab=64, n_heads=2,
                n_kv_heads=1, head_dim=16, moe_d_ff=16, total_steps=40)
    base.update(kw)
    return Config(**base)


def batch(cfg, seed=0):
    k = jax.random.PRNGKey(seed)
    tok = jax.random.randint(k, (cfg.batch_size, cfg.seq_len), 0, cfg.vocab)
    return tok, jnp.roll(tok, -1, axis=1)


ARCH_CASES = [
    ("qwen3", "vanilla", dict(qk_norm=True)),
    ("qwen3", "lpr", dict(qk_norm=True)),
    ("deepseek", "deepseek", dict(n_shared_experts=2)),
    ("deepseek", "lpr", dict(n_shared_experts=2)),
    ("mixtral", "vanilla", {}),
    ("mixtral", "lpr", {}),
]


@pytest.mark.parametrize("arch,router,extra", ARCH_CASES)
def test_forward_all_archs(arch, router, extra):
    cfg = tiny_cfg(arch=arch, router=router, **extra)
    params = init_params(jax.random.PRNGKey(0), cfg)
    tok, tgt = batch(cfg)
    out = forward(params, tok, tgt, cfg, rng=jax.random.PRNGKey(1))
    assert out.load.shape == (cfg.n_layers, cfg.n_experts)
    assert np.isfinite(float(out.loss))
    # fresh model on vocab-64 data: loss ~= ln(64)
    assert abs(float(out.loss) - np.log(cfg.vocab)) < 1.0
    total = cfg.n_layers * cfg.batch_size * cfg.seq_len * cfg.top_k
    assert float(jnp.sum(out.load)) == pytest.approx(total)


@pytest.mark.parametrize("router", ["vanilla", "deepseek", "lpr"])
def test_train_step_reduces_loss(router):
    cfg = tiny_cfg(router=router,
                   n_shared_experts=2 if router == "deepseek" else 0)
    params, m, v = T.init_state(jax.random.PRNGKey(0), cfg)
    lw = jnp.array(cfg.default_loss_weights(), jnp.float32)
    tok, tgt = batch(cfg)
    step = jax.jit(lambda p, m, v, s: T.train_step(
        p, m, v, s, lw, tok, tgt, cfg))
    losses = []
    for i in range(14):
        params, m, v, metrics, _ = step(params, m, v, jnp.int32(i))
        losses.append(float(metrics[0]))
    # memorizing one small batch must cut loss quickly
    assert losses[-1] < losses[0] - 0.2, losses


def test_eval_matches_forward_no_noise():
    cfg = tiny_cfg(router="lpr")
    params, _, _ = T.init_state(jax.random.PRNGKey(0), cfg)
    tok, tgt = batch(cfg)
    m1, l1 = T.eval_step(params, tok, tgt, cfg)
    m2, l2 = T.eval_step(params, tok, tgt, cfg)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2))
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2))


def test_wsd_schedule_shape():
    cfg = tiny_cfg(total_steps=1000)
    # note: step 750 is exactly the stable->decay boundary (cos(0)=1,
    # lr still at peak); probe inside the decay span instead.
    lr = [float(T.wsd_lr(jnp.int32(s), cfg))
          for s in [0, 25, 50, 400, 880, 999]]
    assert lr[0] < lr[1] < lr[2]                      # warmup rises
    assert lr[2] == pytest.approx(cfg.lr, rel=1e-3)   # plateau at peak
    assert lr[3] == pytest.approx(cfg.lr, rel=1e-3)   # stable phase
    assert lr[4] < cfg.lr                             # decaying
    assert lr[5] == pytest.approx(cfg.lr * cfg.min_lr_ratio, rel=0.05)


def test_grad_clip_caps_global_norm():
    g = {"a": jnp.full((10,), 10.0), "b": jnp.full((10,), -10.0)}
    clipped, gnorm = T.clip_by_global_norm(g, 1.0)
    got = float(jnp.sqrt(sum(jnp.sum(x * x)
                             for x in jax.tree.leaves(clipped))))
    assert got == pytest.approx(1.0, rel=1e-4)
    assert float(gnorm) == pytest.approx(np.sqrt(2000), rel=1e-4)


def test_decay_mask_skips_vectors():
    cfg = tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    mask = T._decay_mask(params)
    flat = jax.tree_util.tree_leaves_with_path(mask)
    for path, val in flat:
        name = jax.tree_util.keystr(path)
        if "norm" in name or "b_mu" in name or "b_lv" in name \
                or "bias" in name:
            assert val == 0.0, name


def test_deepseek_bias_moves_toward_balance():
    cfg = tiny_cfg(router="deepseek")
    params, m, v = T.init_state(jax.random.PRNGKey(0), cfg)
    lw = jnp.array(cfg.default_loss_weights(), jnp.float32)
    tok, tgt = batch(cfg)
    b0 = params["layers"][0]["moe"]["router"]["bias"]
    params, m, v, _, load = T.train_step(params, m, v, jnp.int32(0), lw,
                                         tok, tgt, cfg)
    b1 = params["layers"][0]["moe"]["router"]["bias"]
    db = np.asarray(b1 - b0)
    ld = np.asarray(load[0])
    over = ld > ld.mean()
    assert (db[over] <= 0).all() and (db[~over] >= 0).all()


def test_ema_alpha_moves_prototypes():
    cfg = tiny_cfg(router="lpr")
    params, m, v = T.init_state(jax.random.PRNGKey(0), cfg)
    tok, tgt = batch(cfg)
    lw_off = jnp.array(cfg.default_loss_weights(), jnp.float32)
    lw_on = lw_off.at[6].set(0.5)
    # zero all gradient-based weights to isolate the EMA path
    lw_off = lw_off.at[0].set(0.0)
    lw_on = lw_on.at[0].set(0.0)
    p_off, *_ = T.train_step(params, m, v, jnp.int32(0), lw_off, tok, tgt,
                             cfg)
    p_on, *_ = T.train_step(params, m, v, jnp.int32(0), lw_on, tok, tgt,
                            cfg)
    d = np.abs(np.asarray(p_on["layers"][0]["moe"]["router"]["proto_mu"])
               - np.asarray(p_off["layers"][0]["moe"]["router"]
                            ["proto_mu"]))
    assert d.max() > 1e-4


def test_loss_weights_gate_regularizers():
    cfg = tiny_cfg(router="lpr")
    params = init_params(jax.random.PRNGKey(0), cfg)
    tok, tgt = batch(cfg)
    rng = jax.random.PRNGKey(1)
    lw0 = jnp.zeros((8,), jnp.float32)
    lw1 = jnp.array(cfg.default_loss_weights(), jnp.float32)
    t0, out0 = total_loss(params, tok, tgt, cfg, rng, lw0)
    t1, out1 = total_loss(params, tok, tgt, cfg, rng, lw1)
    assert float(t0) == pytest.approx(float(out0.loss), rel=1e-6)
    assert float(t1) > float(out1.loss)  # regularizers add positive mass
